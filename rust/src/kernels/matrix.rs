//! Tiled empirical kernel-matrix assembly.
//!
//! For radial kernels the pairwise squared distances are expanded as
//! `‖x‖² + ‖y‖² − 2·xyᵀ`: the cross term is one call into the packed
//! micro-kernel GEMM core (`linalg::matmul_a_bt`), and the distances are
//! finished + mapped in a second, elementwise parallel pass (the same
//! schedule the L1 Pallas kernel uses on TPU: the cross term feeds the
//! MXU, the kernel map is VPU work). The two passes stay split so the
//! distance arithmetic vectorises independently of the transcendental,
//! which itself goes through the batched `Kernel::map_sq_dist` (fast
//! vectorizable exp). Non-radial kernels fall back to direct evaluation.

use super::functions::Kernel;
use crate::linalg::simd;
use crate::linalg::{matmul_a_bt, mirror_lower_from_upper, syrk_a_at_upper, Matrix};
use crate::pool;

/// Row-tile height for the parallel split. One tile's working set is
/// `TILE×p` (X rows) + `TILE×cols` (output rows) — L2-resident for the
/// shapes in the paper's sweeps.
const TILE: usize = 128;

/// Diagnostic instrumentation for the streamed-pipeline contract: records
/// the largest **square self-assembly** (`cross_kernel` with `a is b`,
/// i.e. a full `n×n` Gram materialisation) seen on the calling thread.
/// Streamed code paths (`GramOperator`, sketched fits, BLESS, top-k
/// K-satisfiability) are asserted to keep this below the dataset size —
/// the "never allocates `n×n`" acceptance gate, enforced by tests without
/// a custom allocator. Thread-local so concurrently running tests cannot
/// pollute each other's readings.
pub mod assembly_guard {
    use std::cell::Cell;

    thread_local! {
        static MAX_SQUARE: Cell<usize> = Cell::new(0);
    }

    /// Reset the calling thread's high-water mark to zero.
    pub fn reset() {
        MAX_SQUARE.with(|c| c.set(0));
    }

    /// Largest square self-assembly since the last [`reset`] (0 = none).
    pub fn max_square() -> usize {
        MAX_SQUARE.with(|c| c.get())
    }

    pub(crate) fn record(n: usize) {
        MAX_SQUARE.with(|c| c.set(c.get().max(n)));
    }
}

/// Full symmetric empirical kernel matrix `K[i,j] = k(xᵢ, xⱼ)` for the rows
/// of `x` (`n × p`). Dense consumers only — anything that merely needs
/// `K`-products should stream through
/// [`GramOperator`](super::GramOperator) instead of paying `O(n²)` memory.
pub fn kernel_matrix(kernel: &Kernel, x: &Matrix) -> Matrix {
    cross_kernel(kernel, x, x)
}

/// Rectangular cross-kernel `K[i,j] = k(aᵢ, bⱼ)` (`a`: `na × p`, `b`:
/// `nb × p`). This is the single assembly routine; `kernel_matrix` is the
/// square case. When `a` and `b` are *the same matrix* (pointer equality —
/// the `kernel_matrix` route), only the upper triangle is assembled and
/// mapped: the cross term goes through the upper-tile SYRK, the norm fold
/// and the transcendental kernel map run on `j ≥ i` only, and the lower
/// triangle is mirrored with the cache-blocked transposed copy — ~2× less
/// GEMM *and* ~2× fewer `exp` evaluations, bitwise identical to the full
/// rectangular computation (which is exactly symmetric: every `(i,j)` /
/// `(j,i)` pair sums the same products in the same order).
pub fn cross_kernel(kernel: &Kernel, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "cross_kernel: feature dims differ");
    let (na, nb, p) = (a.rows(), b.rows(), a.cols());
    if na == 0 || nb == 0 {
        return Matrix::zeros(na, nb);
    }
    let square = std::ptr::eq(a, b);
    if square {
        assembly_guard::record(na);
    }
    if kernel.is_radial() {
        // precompute row squared norms
        let anorm: Vec<f64> = (0..na).map(|i| sqnorm(a.row(i))).collect();
        let bnorm: Vec<f64> = if square {
            anorm.clone()
        } else {
            (0..nb).map(|j| sqnorm(b.row(j))).collect()
        };
        // pass 0: the cross term A·Bᵀ through the packed GEMM core (upper
        // tiles only in the symmetric case); the result buffer *is* the
        // kernel matrix, transformed in place
        let mut k = if square {
            syrk_a_at_upper(a)
        } else {
            matmul_a_bt(a, b)
        };
        let kern = *kernel;
        // dispatch sampled once on the calling thread (pool workers would
        // not see a scoped override), passed into workers by value
        let imp = simd::active();
        pool::scope_chunks(k.data_mut(), TILE * nb, |tile_idx, chunk| {
            let r0 = tile_idx * TILE;
            for (li, krow) in chunk.chunks_mut(nb).enumerate() {
                let i = r0 + li;
                let an = anorm[i];
                // pass 1 (vectorizable): fold the norms into
                // d²(i, j) = ‖a_i‖² + ‖b_j‖² − 2·a_i·b_j over the GEMM row;
                // pass 2: the batched (exp-bound) kernel map. Splitting
                // the passes lets the distance loop vectorize
                // independently of the transcendental. Symmetric case:
                // j ≥ i only — the mirror below fills the rest.
                let j0 = if square { i } else { 0 };
                let tail = &mut krow[j0..];
                for (kv, bn) in tail.iter_mut().zip(bnorm[j0..].iter()) {
                    *kv = an + bn - 2.0 * *kv;
                }
                kern.map_sq_dist_with(imp, tail);
            }
        });
        if square {
            mirror_lower_from_upper(&mut k);
        }
        return k;
    }
    let mut k = Matrix::zeros(na, nb);
    let adat = a.data();
    let bdat = b.data();
    let kern = *kernel;
    pool::scope_chunks(k.data_mut(), TILE * nb, |tile_idx, chunk| {
        let r0 = tile_idx * TILE;
        for (li, krow) in chunk.chunks_mut(nb).enumerate() {
            let i = r0 + li;
            let arow = &adat[i * p..(i + 1) * p];
            let j0 = if square { i } else { 0 };
            for (j, kv) in krow.iter_mut().enumerate().skip(j0) {
                *kv = kern.eval(arow, &bdat[j * p..(j + 1) * p]);
            }
        }
    });
    if square {
        mirror_lower_from_upper(&mut k);
    }
    k
}

/// **Row-stable** rectangular cross-kernel: row `i` of the result is
/// bitwise a function of `aᵢ` and `b` only, independent of how many
/// other rows share the call. [`cross_kernel`] does not promise this:
/// its cross term goes through the plain GEMM entry, whose small-product
/// shortcut changes accumulation order with the batch shape. Here the
/// radial cross term is routed through
/// [`matmul_a_bt_rowstable`](crate::linalg::matmul_a_bt_rowstable)
/// (always the packed path; per-row outputs position-independent), the
/// norm fold is per-row arithmetic, and the batched kernel map is
/// elementwise with padded-lane tails — so the whole row is invariant
/// under batching. This is the serving-plane assembly route
/// (`SketchedKrr::predict`): a prediction must not depend on the batch
/// the micro-batcher coalesced it into. Never takes the symmetric
/// `a is b` shortcut; non-radial kernels use direct evaluation, which is
/// row-independent by construction.
pub fn cross_kernel_rowstable(kernel: &Kernel, a: &Matrix, b: &Matrix) -> Matrix {
    use crate::linalg::matmul_a_bt_rowstable;
    assert_eq!(a.cols(), b.cols(), "cross_kernel_rowstable: feature dims");
    let (na, nb, p) = (a.rows(), b.rows(), a.cols());
    if na == 0 || nb == 0 {
        return Matrix::zeros(na, nb);
    }
    if kernel.is_radial() {
        let anorm: Vec<f64> = (0..na).map(|i| sqnorm(a.row(i))).collect();
        let bnorm: Vec<f64> = (0..nb).map(|j| sqnorm(b.row(j))).collect();
        let mut k = matmul_a_bt_rowstable(a, b);
        let kern = *kernel;
        let imp = simd::active();
        pool::scope_chunks(k.data_mut(), TILE * nb, |tile_idx, chunk| {
            let r0 = tile_idx * TILE;
            for (li, krow) in chunk.chunks_mut(nb).enumerate() {
                let an = anorm[r0 + li];
                for (kv, bn) in krow.iter_mut().zip(bnorm.iter()) {
                    *kv = an + bn - 2.0 * *kv;
                }
                kern.map_sq_dist_with(imp, krow);
            }
        });
        return k;
    }
    let mut k = Matrix::zeros(na, nb);
    let adat = a.data();
    let bdat = b.data();
    let kern = *kernel;
    pool::scope_chunks(k.data_mut(), TILE * nb, |tile_idx, chunk| {
        let r0 = tile_idx * TILE;
        for (li, krow) in chunk.chunks_mut(nb).enumerate() {
            let arow = &adat[(r0 + li) * p..(r0 + li + 1) * p];
            for (j, kv) in krow.iter_mut().enumerate() {
                *kv = kern.eval(arow, &bdat[j * p..(j + 1) * p]);
            }
        }
    });
    k
}

/// Single-precision cross-kernel block for the opt-in `Precision::F32`
/// assembly path: the `na × nb` kernel values as a row-major `Vec<f32>`,
/// never materialising an f64 copy. Features are narrowed once, row
/// norms / dot products / the kernel map all run in f32 (8-lane `exp`
/// under AVX2 dispatch), and callers widen once per consumed element —
/// `GramOperator` accumulates its tile products in f32 and widens per
/// output entry before the f64 `d×d` solves. Radial kernels only.
///
/// Determinism: each output row is produced by exactly one worker with a
/// fixed j-ascending loop, so results are bitwise independent of the
/// thread count (same contract as [`cross_kernel`]).
pub(crate) fn cross_kernel_rows_f32(kernel: &Kernel, a: &Matrix, b: &Matrix) -> Vec<f32> {
    assert!(
        kernel.is_radial(),
        "cross_kernel_rows_f32: radial kernels only"
    );
    assert_eq!(a.cols(), b.cols(), "cross_kernel_rows_f32: feature dims");
    let (na, nb, p) = (a.rows(), b.rows(), a.cols());
    let mut k = vec![0.0f32; na * nb];
    if na == 0 || nb == 0 {
        return k;
    }
    let af: Vec<f32> = a.data().iter().map(|&v| v as f32).collect();
    let bf: Vec<f32> = b.data().iter().map(|&v| v as f32).collect();
    let bnorm: Vec<f32> = (0..nb)
        .map(|j| sqnorm_f32(&bf[j * p..(j + 1) * p]))
        .collect();
    let kern = *kernel;
    let imp = simd::active();
    let (af, bf, bnorm) = (&af, &bf, &bnorm);
    pool::scope_chunks(&mut k, TILE * nb, |tile_idx, chunk| {
        let r0 = tile_idx * TILE;
        for (li, krow) in chunk.chunks_mut(nb).enumerate() {
            let i = r0 + li;
            let arow = &af[i * p..(i + 1) * p];
            let an = sqnorm_f32(arow);
            for (j, kv) in krow.iter_mut().enumerate() {
                let brow = &bf[j * p..(j + 1) * p];
                let dot: f32 = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
                *kv = an + bnorm[j] - 2.0 * dot;
            }
            kern.map_sq_dist_f32(imp, krow);
        }
    });
    k
}

/// [`cross_kernel_rows_f32`] widened into the standard f64 [`Matrix`] —
/// for consumers (and the bench) that want the f32-assembled block in
/// the common matrix type. Accuracy bounds for the narrowed path are
/// gated in `EXPERIMENTS.md` §Mixed-precision.
pub(crate) fn cross_kernel_f32(kernel: &Kernel, a: &Matrix, b: &Matrix) -> Matrix {
    let rows = cross_kernel_rows_f32(kernel, a, b);
    let mut out = Matrix::zeros(a.rows(), b.rows());
    for (dst, src) in out.data_mut().iter_mut().zip(rows.iter()) {
        *dst = *src as f64;
    }
    out
}

fn sqnorm_f32(v: &[f32]) -> f32 {
    v.iter().map(|x| x * x).sum()
}

/// Selected kernel columns `K[:, idx]` without forming all of `K` — the
/// Nyström / sub-sampling fast path (`O(n·d)` evaluations).
pub fn kernel_cols(kernel: &Kernel, x: &Matrix, idx: &[usize]) -> Matrix {
    let landmarks = gather_rows(x, idx);
    cross_kernel(kernel, x, &landmarks)
}

/// Diagonal of the kernel matrix.
pub fn kernel_diag(kernel: &Kernel, x: &Matrix) -> Vec<f64> {
    (0..x.rows()).map(|i| kernel.diag_value(x.row(i))).collect()
}

/// Copy selected rows of `x` into a new matrix.
pub fn gather_rows(x: &Matrix, idx: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(idx.len(), x.cols());
    for (r, &i) in idx.iter().enumerate() {
        out.row_mut(r).copy_from_slice(x.row(i));
    }
    out
}

fn sqnorm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randx(r: &mut Pcg64, n: usize, p: usize) -> Matrix {
        Matrix::from_fn(n, p, |_, _| r.normal())
    }

    #[test]
    fn matches_direct_eval_all_kernels() {
        let mut r = Pcg64::seed(61);
        let x = randx(&mut r, 37, 3);
        for k in [
            Kernel::gaussian(1.2),
            Kernel::matern(0.5, 0.8),
            Kernel::matern(1.5, 1.5),
            Kernel::matern(2.5, 1.0),
            Kernel::laplacian(1.0),
            Kernel::polynomial(2.0, 2),
            Kernel::linear(),
        ] {
            let km = kernel_matrix(&k, &x);
            for i in 0..x.rows() {
                for j in 0..x.rows() {
                    let want = k.eval(x.row(i), x.row(j));
                    assert!(
                        (km[(i, j)] - want).abs() < 1e-10,
                        "{} ({i},{j}): {} vs {want}",
                        k.name(),
                        km[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_with_unit_diag() {
        let mut r = Pcg64::seed(62);
        let x = randx(&mut r, 50, 4);
        let km = kernel_matrix(&Kernel::gaussian(1.0), &x);
        for i in 0..50 {
            assert!((km[(i, i)] - 1.0).abs() < 1e-9);
            for j in 0..50 {
                assert!((km[(i, j)] - km[(j, i)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cross_kernel_rectangular() {
        let mut r = Pcg64::seed(63);
        let a = randx(&mut r, 10, 2);
        let b = randx(&mut r, 7, 2);
        let k = Kernel::matern(1.5, 1.0);
        let km = cross_kernel(&k, &a, &b);
        assert_eq!((km.rows(), km.cols()), (10, 7));
        assert!((km[(3, 5)] - k.eval(a.row(3), b.row(5))).abs() < 1e-12);
    }

    #[test]
    fn kernel_cols_matches_full_matrix_columns() {
        let mut r = Pcg64::seed(64);
        let x = randx(&mut r, 30, 3);
        let k = Kernel::gaussian(0.9);
        let full = kernel_matrix(&k, &x);
        let idx = [4usize, 17, 17, 2];
        let cols = kernel_cols(&k, &x, &idx);
        for i in 0..30 {
            for (c, &j) in idx.iter().enumerate() {
                assert!((cols[(i, c)] - full[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn psd_check_via_quadratic_form() {
        let mut r = Pcg64::seed(65);
        let x = randx(&mut r, 25, 3);
        let km = kernel_matrix(&Kernel::gaussian(1.0), &x);
        for _ in 0..5 {
            let v: Vec<f64> = (0..25).map(|_| r.normal()).collect();
            let q: f64 = km
                .matvec(&v)
                .iter()
                .zip(v.iter())
                .map(|(a, b)| a * b)
                .sum();
            assert!(q > -1e-9, "quadratic form negative: {q}");
        }
    }

    #[test]
    fn diag_values() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(kernel_diag(&Kernel::gaussian(1.0), &x), vec![1.0, 1.0]);
        assert_eq!(kernel_diag(&Kernel::linear(), &x), vec![5.0, 0.0]);
    }

    /// The symmetric fast path (`a is b`: upper-tile SYRK, `j ≥ i` kernel
    /// map, cache-blocked mirror) is **bitwise** the rectangular
    /// computation it shortcuts — checked by defeating the pointer
    /// equality with a clone. Covers the GEMM-routed radial path, the
    /// direct-eval path, and shapes on both sides of the small-flops
    /// cutoff.
    #[test]
    fn symmetric_fast_path_matches_rectangular_assembly_bitwise() {
        let mut r = Pcg64::seed(0x9004);
        for &n in &[9usize, 30, 200] {
            let x = randx(&mut r, n, 4);
            let x2 = x.clone();
            for kern in [
                Kernel::gaussian(0.8),
                Kernel::matern(1.5, 1.0),
                Kernel::laplacian(0.9),
                Kernel::polynomial(1.5, 2),
            ] {
                let fast = kernel_matrix(&kern, &x); // a is b: triangle + mirror
                let full = cross_kernel(&kern, &x, &x2); // distinct refs: full rectangle
                assert_eq!(fast.data(), full.data(), "{} n={n}", kern.name());
            }
        }
    }

    /// The f32 assembly tracks the f64 assembly to single-precision
    /// accuracy (kernel values live in [0, 1], so absolute ~1e-5 is the
    /// right scale), and is bitwise thread-count-independent.
    #[test]
    fn cross_kernel_f32_tracks_f64_assembly() {
        use crate::pool;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut r = Pcg64::seed(0x9006);
        let a = randx(&mut r, 160, 5);
        let b = randx(&mut r, 70, 5);
        for kern in [Kernel::gaussian(0.8), Kernel::matern(1.5, 1.0)] {
            let want = cross_kernel(&kern, &a, &b);
            let got = cross_kernel_f32(&kern, &a, &b);
            let mut worst = 0.0f64;
            for (g, w) in got.data().iter().zip(want.data().iter()) {
                worst = worst.max((g - w).abs());
            }
            assert!(worst < 5e-5, "{} worst abs err {worst}", kern.name());
            let before = pool::num_threads();
            pool::set_num_threads(1);
            let serial = cross_kernel_rows_f32(&kern, &a, &b);
            pool::set_num_threads(4);
            let parallel = cross_kernel_rows_f32(&kern, &a, &b);
            pool::set_num_threads(before);
            assert_eq!(serial, parallel, "{}", kern.name());
        }
    }

    /// The serving contract end-to-end at the assembly layer: a single
    /// query row assembled alone is bitwise the same row assembled in a
    /// batch of any size or position, under both dispatch modes — and
    /// the row-stable route agrees numerically with the plain one.
    #[test]
    fn rowstable_assembly_is_bitwise_batch_invariant() {
        use crate::linalg::{with_kernel, KernelImpl};
        let mut r = Pcg64::seed(0x9007);
        let landmarks = randx(&mut r, 14, 6);
        let batch = randx(&mut r, 41, 6);
        for kern in [Kernel::gaussian(0.8), Kernel::matern(1.5, 1.0), Kernel::polynomial(1.5, 2)] {
            for imp in [KernelImpl::Scalar, crate::linalg::simd::active()] {
                with_kernel(imp, || {
                    let full = cross_kernel_rowstable(&kern, &batch, &landmarks);
                    for i in [0usize, 7, 40] {
                        let one = Matrix::from_fn(1, 6, |_, j| batch[(i, j)]);
                        let solo = cross_kernel_rowstable(&kern, &one, &landmarks);
                        for j in 0..14 {
                            assert_eq!(
                                solo[(0, j)].to_bits(),
                                full[(i, j)].to_bits(),
                                "{} row {i} col {j} {imp:?}",
                                kern.name()
                            );
                        }
                    }
                    let plain = cross_kernel(&kern, &batch, &landmarks);
                    for (g, w) in full.data().iter().zip(plain.data().iter()) {
                        assert!((g - w).abs() < 1e-12, "{} vs plain", kern.name());
                    }
                });
            }
        }
    }

    /// The guard sees square self-assemblies and nothing else.
    #[test]
    fn assembly_guard_records_square_assembly_only() {
        assembly_guard::reset();
        let mut r = Pcg64::seed(0x9005);
        let a = randx(&mut r, 40, 3);
        let b = randx(&mut r, 25, 3);
        let _ = cross_kernel(&Kernel::gaussian(1.0), &a, &b);
        let _ = kernel_cols(&Kernel::gaussian(1.0), &a, &[1, 5, 7]);
        assert_eq!(assembly_guard::max_square(), 0, "rectangular must not record");
        let _ = kernel_matrix(&Kernel::gaussian(1.0), &a);
        assert_eq!(assembly_guard::max_square(), 40);
        assembly_guard::reset();
        assert_eq!(assembly_guard::max_square(), 0);
    }

    /// Assembly through the packed GEMM + elementwise passes is bitwise
    /// independent of the thread count (same guarantee as the GEMM core:
    /// fixed chunk boundaries, one owner per output row).
    #[test]
    fn cross_kernel_parallel_matches_serial_exactly() {
        use crate::pool;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut r = Pcg64::seed(0x9003);
        // > TILE rows so the elementwise pass actually splits, and big
        // enough that the cross term takes the packed (parallel) path
        let a = randx(&mut r, 300, 5);
        let b = randx(&mut r, 150, 5);
        let before = pool::num_threads();
        for kern in [Kernel::gaussian(0.8), Kernel::matern(1.5, 1.0), Kernel::polynomial(1.5, 2)] {
            pool::set_num_threads(1);
            let serial = cross_kernel(&kern, &a, &b);
            pool::set_num_threads(4);
            let parallel = cross_kernel(&kern, &a, &b);
            assert_eq!(serial.data(), parallel.data(), "{}", kern.name());
        }
        pool::set_num_threads(before);
    }
}
