//! Tiled empirical kernel-matrix assembly.
//!
//! For radial kernels the pairwise squared distances are expanded as
//! `‖x‖² + ‖y‖² − 2·xyᵀ`: the cross term is one call into the packed
//! micro-kernel GEMM core (`linalg::matmul_a_bt`), and the distances are
//! finished + mapped in a second, elementwise parallel pass (the same
//! schedule the L1 Pallas kernel uses on TPU: the cross term feeds the
//! MXU, the kernel map is VPU work). The two passes stay split so the
//! distance arithmetic vectorises independently of the transcendental,
//! which itself goes through the batched `Kernel::map_sq_dist` (fast
//! vectorizable exp). Non-radial kernels fall back to direct evaluation.

use super::functions::Kernel;
use crate::linalg::{matmul_a_bt, Matrix};
use crate::pool;

/// Row-tile height for the parallel split. One tile's working set is
/// `TILE×p` (X rows) + `TILE×cols` (output rows) — L2-resident for the
/// shapes in the paper's sweeps.
const TILE: usize = 128;

/// Full symmetric empirical kernel matrix `K[i,j] = k(xᵢ, xⱼ)` for the rows
/// of `x` (`n × p`).
pub fn kernel_matrix(kernel: &Kernel, x: &Matrix) -> Matrix {
    cross_kernel(kernel, x, x)
}

/// Rectangular cross-kernel `K[i,j] = k(aᵢ, bⱼ)` (`a`: `na × p`, `b`:
/// `nb × p`). This is the single assembly routine; `kernel_matrix` is the
/// square case (the symmetric savings are deliberately not exploited — the
/// tile GEMM is faster in practice than a triangular gather, and it keeps
/// one code path to optimise/verify).
pub fn cross_kernel(kernel: &Kernel, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.cols(), "cross_kernel: feature dims differ");
    let (na, nb, p) = (a.rows(), b.rows(), a.cols());
    if na == 0 || nb == 0 {
        return Matrix::zeros(na, nb);
    }
    if kernel.is_radial() {
        // precompute row squared norms
        let anorm: Vec<f64> = (0..na).map(|i| sqnorm(a.row(i))).collect();
        let bnorm: Vec<f64> = (0..nb).map(|j| sqnorm(b.row(j))).collect();
        // pass 0: the cross term A·Bᵀ through the packed GEMM core; the
        // result buffer *is* the kernel matrix, transformed in place
        let mut k = matmul_a_bt(a, b);
        let kern = *kernel;
        pool::scope_chunks(k.data_mut(), TILE * nb, |tile_idx, chunk| {
            let r0 = tile_idx * TILE;
            for (li, krow) in chunk.chunks_mut(nb).enumerate() {
                let an = anorm[r0 + li];
                // pass 1 (vectorizable): fold the norms into
                // d²(i, j) = ‖a_i‖² + ‖b_j‖² − 2·a_i·b_j over the GEMM row;
                // pass 2: the batched (exp-bound) kernel map. Splitting
                // the passes lets the distance loop vectorize
                // independently of the transcendental.
                for (kv, bn) in krow.iter_mut().zip(bnorm.iter()) {
                    *kv = an + bn - 2.0 * *kv;
                }
                kern.map_sq_dist(krow);
            }
        });
        return k;
    }
    let mut k = Matrix::zeros(na, nb);
    let adat = a.data();
    let bdat = b.data();
    let kern = *kernel;
    pool::scope_chunks(k.data_mut(), TILE * nb, |tile_idx, chunk| {
        let r0 = tile_idx * TILE;
        for (li, krow) in chunk.chunks_mut(nb).enumerate() {
            let i = r0 + li;
            let arow = &adat[i * p..(i + 1) * p];
            for (j, kv) in krow.iter_mut().enumerate() {
                *kv = kern.eval(arow, &bdat[j * p..(j + 1) * p]);
            }
        }
    });
    k
}

/// Selected kernel columns `K[:, idx]` without forming all of `K` — the
/// Nyström / sub-sampling fast path (`O(n·d)` evaluations).
pub fn kernel_cols(kernel: &Kernel, x: &Matrix, idx: &[usize]) -> Matrix {
    let landmarks = gather_rows(x, idx);
    cross_kernel(kernel, x, &landmarks)
}

/// Diagonal of the kernel matrix.
pub fn kernel_diag(kernel: &Kernel, x: &Matrix) -> Vec<f64> {
    (0..x.rows()).map(|i| kernel.diag_value(x.row(i))).collect()
}

/// Copy selected rows of `x` into a new matrix.
pub fn gather_rows(x: &Matrix, idx: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(idx.len(), x.cols());
    for (r, &i) in idx.iter().enumerate() {
        out.row_mut(r).copy_from_slice(x.row(i));
    }
    out
}

fn sqnorm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn randx(r: &mut Pcg64, n: usize, p: usize) -> Matrix {
        Matrix::from_fn(n, p, |_, _| r.normal())
    }

    #[test]
    fn matches_direct_eval_all_kernels() {
        let mut r = Pcg64::seed(61);
        let x = randx(&mut r, 37, 3);
        for k in [
            Kernel::gaussian(1.2),
            Kernel::matern(0.5, 0.8),
            Kernel::matern(1.5, 1.5),
            Kernel::matern(2.5, 1.0),
            Kernel::laplacian(1.0),
            Kernel::polynomial(2.0, 2),
            Kernel::linear(),
        ] {
            let km = kernel_matrix(&k, &x);
            for i in 0..x.rows() {
                for j in 0..x.rows() {
                    let want = k.eval(x.row(i), x.row(j));
                    assert!(
                        (km[(i, j)] - want).abs() < 1e-10,
                        "{} ({i},{j}): {} vs {want}",
                        k.name(),
                        km[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_with_unit_diag() {
        let mut r = Pcg64::seed(62);
        let x = randx(&mut r, 50, 4);
        let km = kernel_matrix(&Kernel::gaussian(1.0), &x);
        for i in 0..50 {
            assert!((km[(i, i)] - 1.0).abs() < 1e-9);
            for j in 0..50 {
                assert!((km[(i, j)] - km[(j, i)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cross_kernel_rectangular() {
        let mut r = Pcg64::seed(63);
        let a = randx(&mut r, 10, 2);
        let b = randx(&mut r, 7, 2);
        let k = Kernel::matern(1.5, 1.0);
        let km = cross_kernel(&k, &a, &b);
        assert_eq!((km.rows(), km.cols()), (10, 7));
        assert!((km[(3, 5)] - k.eval(a.row(3), b.row(5))).abs() < 1e-12);
    }

    #[test]
    fn kernel_cols_matches_full_matrix_columns() {
        let mut r = Pcg64::seed(64);
        let x = randx(&mut r, 30, 3);
        let k = Kernel::gaussian(0.9);
        let full = kernel_matrix(&k, &x);
        let idx = [4usize, 17, 17, 2];
        let cols = kernel_cols(&k, &x, &idx);
        for i in 0..30 {
            for (c, &j) in idx.iter().enumerate() {
                assert!((cols[(i, c)] - full[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn psd_check_via_quadratic_form() {
        let mut r = Pcg64::seed(65);
        let x = randx(&mut r, 25, 3);
        let km = kernel_matrix(&Kernel::gaussian(1.0), &x);
        for _ in 0..5 {
            let v: Vec<f64> = (0..25).map(|_| r.normal()).collect();
            let q: f64 = km
                .matvec(&v)
                .iter()
                .zip(v.iter())
                .map(|(a, b)| a * b)
                .sum();
            assert!(q > -1e-9, "quadratic form negative: {q}");
        }
    }

    #[test]
    fn diag_values() {
        let x = Matrix::from_vec(2, 2, vec![1.0, 2.0, 0.0, 0.0]);
        assert_eq!(kernel_diag(&Kernel::gaussian(1.0), &x), vec![1.0, 1.0]);
        assert_eq!(kernel_diag(&Kernel::linear(), &x), vec![5.0, 0.0]);
    }

    /// Assembly through the packed GEMM + elementwise passes is bitwise
    /// independent of the thread count (same guarantee as the GEMM core:
    /// fixed chunk boundaries, one owner per output row).
    #[test]
    fn cross_kernel_parallel_matches_serial_exactly() {
        use crate::pool;
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut r = Pcg64::seed(0x9003);
        // > TILE rows so the elementwise pass actually splits, and big
        // enough that the cross term takes the packed (parallel) path
        let a = randx(&mut r, 300, 5);
        let b = randx(&mut r, 150, 5);
        let before = pool::num_threads();
        for kern in [Kernel::gaussian(0.8), Kernel::matern(1.5, 1.0), Kernel::polynomial(1.5, 2)] {
            pool::set_num_threads(1);
            let serial = cross_kernel(&kern, &a, &b);
            pool::set_num_threads(4);
            let parallel = cross_kernel(&kern, &a, &b);
            assert_eq!(serial.data(), parallel.data(), "{}", kern.name());
        }
        pool::set_num_threads(before);
    }
}
