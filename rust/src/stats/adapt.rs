//! Stopping rules for the adaptive-m accumulation loop.
//!
//! Optimal sampling probabilities are rarely available in practice, so the
//! right number of accumulated terms `m` is data-dependent: the adaptive
//! KRR loop ([`crate::krr::SketchedKrr::fit_adaptive`]) grows the sketch
//! and stops when the solution stabilises. Two criteria are combined:
//!
//! * **relative change** — `‖θ_new − θ_old‖ / ‖θ_new‖` below `rel_tol`
//!   for `patience` consecutive rounds (the estimator has converged in the
//!   metric that matters: its own coefficients);
//! * **AMM-error proxy** — the accumulation sketch's sub-sampling variance
//!   decays as `√(n/(d·m))` (paper §3/§5: each column is an average of
//!   `m` rescaled indicator draws, so the `E[SSᵀ] − I` fluctuation and the
//!   AMM error both shrink at the Monte-Carlo rate in `d·m`); once the
//!   proxy is below `amm_tol`, more terms cannot move the estimator by
//!   more than the target accuracy.

/// Relative ℓ₂ change `‖cur − prev‖ / max(‖cur‖, ε)` between two solution
/// vectors (ε guards the all-zero solution).
pub fn rel_change(prev: &[f64], cur: &[f64]) -> f64 {
    assert_eq!(prev.len(), cur.len(), "rel_change: length mismatch");
    let mut num = 0.0;
    let mut den = 0.0;
    for (a, b) in prev.iter().zip(cur.iter()) {
        num += (b - a) * (b - a);
        den += b * b;
    }
    (num / den.max(1e-300)).sqrt()
}

/// Theory-based proxy for the accumulation sketch's remaining error at `m`
/// terms: `√(n/(d·m))`, the Monte-Carlo rate of the `d·m` sub-sampling
/// draws that make up the sketch.
pub fn amm_error_proxy(n: usize, d: usize, m: usize) -> f64 {
    assert!(n > 0 && d > 0 && m > 0);
    (n as f64 / (d * m) as f64).sqrt()
}

/// Stateful stopping rule for the adaptive accumulation loop.
#[derive(Clone, Debug)]
pub struct StoppingRule {
    rel_tol: f64,
    patience: usize,
    min_m: usize,
    amm_tol: Option<f64>,
    hits: usize,
}

impl StoppingRule {
    /// Rule firing after `patience` consecutive rounds with relative
    /// change below `rel_tol` (and at least 2 accumulated terms).
    pub fn new(rel_tol: f64, patience: usize) -> StoppingRule {
        StoppingRule {
            rel_tol,
            patience: patience.max(1),
            min_m: 2,
            amm_tol: None,
            hits: 0,
        }
    }

    /// Don't stop before `m` terms have been accumulated.
    pub fn with_min_m(mut self, m: usize) -> StoppingRule {
        self.min_m = m.max(1);
        self
    }

    /// Also stop once [`amm_error_proxy`] drops below `tol`.
    pub fn with_amm_tol(mut self, tol: f64) -> StoppingRule {
        self.amm_tol = Some(tol);
        self
    }

    /// Record one round (current term count `m`, observed relative change,
    /// current [`amm_error_proxy`]); returns `true` when the loop should
    /// stop.
    pub fn observe(&mut self, m: usize, rel_change: f64, amm_proxy: f64) -> bool {
        if rel_change <= self.rel_tol {
            self.hits += 1;
        } else {
            self.hits = 0;
        }
        if m < self.min_m {
            return false;
        }
        if self.hits >= self.patience {
            return true;
        }
        matches!(self.amm_tol, Some(t) if amm_proxy <= t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_change_basic() {
        assert_eq!(rel_change(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        // ‖(0,1)−(1,0)‖/‖(0,1)‖ = √2
        let c = rel_change(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((c - 2f64.sqrt()).abs() < 1e-12);
        // zero current vector guarded
        assert!(rel_change(&[1.0], &[0.0]).is_finite());
    }

    #[test]
    fn proxy_decays_with_m_and_d() {
        let p1 = amm_error_proxy(1000, 20, 1);
        let p4 = amm_error_proxy(1000, 20, 4);
        assert!((p1 / p4 - 2.0).abs() < 1e-12, "quadruple m halves the proxy");
        assert!(amm_error_proxy(1000, 80, 1) < p1);
    }

    #[test]
    fn patience_requires_consecutive_quiet_rounds() {
        let mut r = StoppingRule::new(1e-2, 2);
        assert!(!r.observe(2, 1e-3, 1.0)); // quiet ×1
        assert!(!r.observe(3, 5e-1, 1.0)); // loud resets
        assert!(!r.observe(4, 1e-3, 1.0)); // quiet ×1
        assert!(r.observe(5, 1e-3, 1.0)); // quiet ×2 → stop
    }

    #[test]
    fn min_m_blocks_early_stop() {
        let mut r = StoppingRule::new(1e-2, 1).with_min_m(8);
        assert!(!r.observe(2, 0.0, 1.0));
        assert!(r.observe(8, 0.0, 1.0));
    }

    #[test]
    fn amm_tol_stops_independently_of_change() {
        let mut r = StoppingRule::new(1e-9, 1).with_amm_tol(0.5);
        assert!(!r.observe(2, 1.0, 0.9));
        assert!(r.observe(3, 1.0, 0.4)); // change still loud, proxy quiet
    }
}
