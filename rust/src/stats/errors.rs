//! Error metrics used by every figure in the paper.
//!
//! The paper's in-sample (semi-)norm is `‖f̂_S − f̂_n‖²_n = (1/n)Σᵢ|f̂_S(xᵢ) −
//! f̂_n(xᵢ)|²` (the displayed definition omits the `1/n`, but the plotted
//! errors decay with n, matching the standard empirical-norm convention
//! also used by Yang et al. 2017 — we follow that convention and note it
//! here).

/// `(1/n) Σ (a_i − b_i)²` — the approximation error between two in-sample
/// prediction vectors (e.g. sketched vs exact KRR).
pub fn in_sample_sq_error(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        / a.len() as f64
}

/// Mean squared error of predictions vs targets.
pub fn mse(pred: &[f64], target: &[f64]) -> f64 {
    in_sample_sq_error(pred, target)
}

/// Held-out test error (alias of [`mse`] with intention-revealing name).
pub fn test_error(pred: &[f64], target: &[f64]) -> f64 {
    mse(pred, target)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_for_identical() {
        assert_eq!(in_sample_sq_error(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn averages_squared_diffs() {
        // diffs: 1, 3 → (1+9)/2 = 5
        assert_eq!(in_sample_sq_error(&[1.0, 0.0], &[0.0, 3.0]), 5.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(in_sample_sq_error(&[], &[]), 0.0);
    }
}
