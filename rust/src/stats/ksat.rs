//! K-satisfiability and incoherence diagnostics.
//!
//! Two routes to the K-satisfiability check: the original
//! [`k_satisfiability`] consumes a full [`SpectralView`] (an `O(n³)`
//! dense eigendecomposition — still required by [`incoherence`] and the
//! statistical dimension, which sum over the whole spectrum), and
//! [`k_satisfiability_topk`], which resolves only the eigenpairs above δ
//! with [`partial_eigh`] and folds the tail condition algebraically, so
//! the diagnostic scales to n where the dense solver does not.

use crate::kernels::GramOperator;
use crate::linalg::{
    eigh, matmul_a_bt, matmul_at_b, op_norm, op_norm_rect, partial_eigh, partial_eigh_op,
    partial_eigh_op_warm, Matrix, SymOp,
};
use crate::sketch::{Sketch, SketchOps};

/// `K/n`, symmetrised — the operator every spectral diagnostic
/// decomposes (shared by [`SpectralView::new`], [`k_satisfiability_topk`]
/// and [`top_sigma`]).
fn kn_normalized(k: &Matrix) -> Matrix {
    let mut kn = k.clone();
    kn.scale(1.0 / k.rows() as f64);
    kn.symmetrize();
    kn
}

/// `U₁ᵀ S` (`dd × d`): the top-`dd` eigenvector block applied to the
/// sketch — row `r` is `(column r of U)ᵀ · S`. Shared by both
/// K-satisfiability routes.
fn u1_t_s(u: &Matrix, dd: usize, s: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(dd, s.cols());
    for r in 0..dd {
        let ucol = u.col(r);
        let v = s.matvec_t(&ucol);
        out.row_mut(r).copy_from_slice(&v);
    }
    out
}

/// Eigendecomposition of `K/n` cached for repeated diagnostics: the bench
/// harness evaluates many sketches against one dataset.
#[derive(Clone, Debug)]
pub struct SpectralView {
    /// Eigenvalues of `K/n`, descending (σ₁ ≥ … ≥ σₙ).
    pub sigma: Vec<f64>,
    /// Matching eigenvectors (columns), i.e. `U` with `K/n = U Σ Uᵀ`.
    pub u: Matrix,
    n: usize,
}

impl SpectralView {
    /// Decompose `K` (the *unscaled* empirical kernel matrix).
    pub fn new(k: &Matrix) -> SpectralView {
        let n = k.rows();
        let kn = kn_normalized(k);
        let (sigma, u) = eigh(&kn).descending();
        SpectralView {
            sigma: sigma.into_iter().map(|s| s.max(0.0)).collect(),
            u,
            n,
        }
    }

    /// `d_δ = min{i : σᵢ ≤ δ} − 1` — the number of eigenvalues above δ.
    pub fn d_delta(&self, delta: f64) -> usize {
        self.sigma.iter().take_while(|&&s| s > delta).count()
    }

    /// Statistical dimension `Σᵢ σᵢ/(σᵢ+δ)`.
    pub fn stat_dim(&self, delta: f64) -> f64 {
        self.sigma.iter().map(|&s| s / (s + delta)).sum()
    }

    /// Number of data points.
    pub fn n(&self) -> usize {
        self.n
    }
}

/// Statistical dimension straight from a kernel matrix.
pub fn stat_dim(k: &Matrix, delta: f64) -> f64 {
    SpectralView::new(k).stat_dim(delta)
}

/// Outcome of the K-satisfiability check (paper Definition 3).
#[derive(Clone, Copy, Debug)]
pub struct KSatReport {
    /// `‖U₁ᵀ S Sᵀ U₁ − I‖_op` — must be ≤ 1/2.
    pub top_distortion: f64,
    /// `‖Sᵀ U₂ Σ₂^{1/2}‖_op` — must be ≤ c·√δ.
    pub tail_norm: f64,
    /// `√δ` for reference (so callers can form the ratio).
    pub sqrt_delta: f64,
    /// `d_δ` used for the split.
    pub d_delta: usize,
    /// Condition 1: top_distortion ≤ 1/2.
    pub cond1: bool,
    /// Condition 2 with the conventional constant c = 1.
    pub cond2: bool,
}

impl KSatReport {
    /// Both conditions hold (c = 1).
    pub fn satisfied(&self) -> bool {
        self.cond1 && self.cond2
    }
}

/// Evaluate K-satisfiability of a sketch for regularisation level `δ`.
pub fn k_satisfiability(view: &SpectralView, sketch: &Sketch, delta: f64) -> KSatReport {
    let n = view.n();
    let dd = view.d_delta(delta).max(1).min(n);
    let s = sketch.to_dense();

    let u1ts = u1_t_s(&view.u, dd, &s);
    // G = U₁ᵀSSᵀU₁ − I
    let mut g = crate::linalg::matmul_a_bt(&u1ts, &u1ts);
    g.add_diag(-1.0);
    let top_distortion = op_norm(&g, 300);

    // SᵀU₂Σ₂^{1/2}  (d × (n−d_δ))
    let tail = {
        let cols = n - dd;
        let mut out = Matrix::zeros(s.cols(), cols);
        for c in 0..cols {
            let j = dd + c;
            let ucol = view.u.col(j);
            let sv = s.matvec_t(&ucol);
            let w = view.sigma[j].max(0.0).sqrt();
            for r in 0..s.cols() {
                out[(r, c)] = sv[r] * w;
            }
        }
        out
    };
    let tail_norm = if n > dd {
        op_norm_rect(&tail, 300)
    } else {
        0.0
    };

    let sqrt_delta = delta.sqrt();
    KSatReport {
        top_distortion,
        tail_norm,
        sqrt_delta,
        d_delta: dd,
        cond1: top_distortion <= 0.5,
        cond2: tail_norm <= sqrt_delta,
    }
}

/// K-satisfiability from the **top spectrum only** — the
/// partial-eigensolver route for large `n`.
///
/// Only the eigenpairs with `σ > δ` are resolved (the block is grown
/// geometrically until the smallest resolved eigenvalue clears the cut);
/// the tail condition never needs `U₂` explicitly because
///
/// ```text
///   (SᵀU₂Σ₂^{1/2})(SᵀU₂Σ₂^{1/2})ᵀ = Sᵀ(K/n)S − (U₁ᵀS)ᵀ Σ₁ (U₁ᵀS)
/// ```
///
/// so `tail_norm² = λ_max` of that `d×d` difference. Matches
/// [`k_satisfiability`] to power-iteration tolerance (`top_distortion`
/// depends only on the span of `U₁`, which both solvers agree on), while
/// replacing the `O(n³)` dense eigendecomposition with `O(n²·d_δ)` work.
pub fn k_satisfiability_topk(k: &Matrix, sketch: &Sketch, delta: f64) -> KSatReport {
    assert_eq!(k.rows(), k.cols(), "k_satisfiability_topk: square kernel");
    let kn = kn_normalized(k);
    k_satisfiability_topk_impl(&kn, sketch, delta)
}

/// [`k_satisfiability_topk`] against a streamed [`GramOperator`] — the
/// large-n route: subspace iteration and the `Sᵀ(K/n)S` tail product
/// consume `K/n` through `O(tile·n)` row panels instead of a dense
/// matrix. Reports match the dense entry point to power-iteration
/// tolerance (the algebra is shared, only the FP grouping of the
/// products differs).
///
/// Caveat: if the spectrum above `δ` is so wide that the resolved block
/// grows to `2b ≥ n`, or the iteration stalls on a clustered spectrum,
/// the partial eigensolver takes its **dense fallback** and assembles
/// `K` after all (converged answers beat memory purity; see
/// [`SymOp::materialize`]). That event is observable through
/// `kernels::assembly_guard` — callers for whom `n×n` is fatal should
/// check it, or pick `δ` so `d_δ ≪ n`.
pub fn k_satisfiability_topk_streamed(
    op: &GramOperator,
    sketch: &Sketch,
    delta: f64,
) -> KSatReport {
    let kn = op.scaled(1.0 / op.n() as f64);
    k_satisfiability_topk_impl(&kn, sketch, delta)
}

/// Shared body: `kn` is the (implicit or dense) normalised operator `K/n`.
fn k_satisfiability_topk_impl<O: SymOp>(kn: &O, sketch: &Sketch, delta: f64) -> KSatReport {
    let n = kn.dim();
    // resolve eigenpairs until the spectrum drops below δ (the U₁/U₂ cut);
    // each enlargement warm-starts from the previous round's Ritz vectors
    let mut r = 16usize.min(n).max(1);
    let mut warm: Option<Matrix> = None;
    let (sigma, u) = loop {
        let pe = partial_eigh_op_warm(kn, r, warm.as_ref());
        if r >= n || pe.w.last().map_or(true, |&w| w <= delta) {
            let clamped: Vec<f64> = pe.w.into_iter().map(|s| s.max(0.0)).collect();
            break (clamped, pe.v);
        }
        r = if pe.is_complete() {
            // the solver already fell back to a full dense decomposition:
            // jump straight to r = n so one final dense solve finishes the
            // job instead of re-paying it once per doubling
            n
        } else {
            (2 * r).min(n)
        };
        warm = Some(pe.v);
    };
    let dd = sigma
        .iter()
        .take_while(|&&s| s > delta)
        .count()
        .max(1)
        .min(sigma.len());
    let s = sketch.to_dense();
    let u1ts = u1_t_s(&u, dd, &s);
    // G = U₁ᵀSSᵀU₁ − I
    let mut g = matmul_a_bt(&u1ts, &u1ts);
    g.add_diag(-1.0);
    let top_distortion = op_norm(&g, 300);

    // tail Gram: Sᵀ(K/n)S − (U₁ᵀS)ᵀ Σ₁ (U₁ᵀS)
    let kns = kn.apply(&s);
    let mut tail_gram = matmul_at_b(&s, &kns);
    let mut w1 = u1ts.clone();
    for row in 0..dd {
        let sig = sigma[row];
        for v in w1.row_mut(row).iter_mut() {
            *v *= sig;
        }
    }
    tail_gram.axpy(-1.0, &matmul_at_b(&u1ts, &w1));
    tail_gram.symmetrize();
    let tail_norm = op_norm(&tail_gram, 300).max(0.0).sqrt();

    let sqrt_delta = delta.sqrt();
    KSatReport {
        top_distortion,
        tail_norm,
        sqrt_delta,
        d_delta: dd,
        cond1: top_distortion <= 0.5,
        cond2: tail_norm <= sqrt_delta,
    }
}

/// Top-`r` eigenvalues of `K/n` (descending, clamped at 0) through the
/// partial eigensolver — for consumers that need only leading spectral
/// mass (e.g. the KPCA recovery benches) and should not pay `O(n³)`.
pub fn top_sigma(k: &Matrix, r: usize) -> Vec<f64> {
    let n = k.rows();
    let kn = kn_normalized(k);
    partial_eigh(&kn, r.min(n))
        .w
        .into_iter()
        .map(|s| s.max(0.0))
        .collect()
}

/// [`top_sigma`] against a streamed [`GramOperator`]: `O(n·b)` working
/// memory per iteration instead of an `O(n²)` dense `K/n`.
pub fn top_sigma_streamed(op: &GramOperator, r: usize) -> Vec<f64> {
    let n = op.n();
    let kn = op.scaled(1.0 / n as f64);
    partial_eigh_op(&kn, r.min(n))
        .w
        .into_iter()
        .map(|s| s.max(0.0))
        .collect()
}

/// Incoherence `M` (paper Theorem 8):
///
/// ```text
///   M = max{ maxᵢ ‖ψ̃ᵢ‖²/pᵢ , maxᵢ (‖ψᵢ‖² − ‖ψ̃ᵢ‖²)/pᵢ }
/// ```
///
/// where `ψᵢ` is the i-th column of `Ψ_δ = [Σ(Σ + δI)⁻¹]^{1/2} Uᵀ` and `ψ̃ᵢ`
/// its first `d_δ` coordinates. (`Σ` here holds eigenvalues of `K/n`; the
/// paper's `nδ` with eigenvalues of `K` is the same quantity.)
pub fn incoherence(view: &SpectralView, probs: &[f64], delta: f64) -> f64 {
    let n = view.n();
    assert_eq!(probs.len(), n);
    let dd = view.d_delta(delta);
    // weight per eigendirection: σ_r/(σ_r + δ)
    let w: Vec<f64> = view.sigma.iter().map(|&s| s / (s + delta)).collect();
    let mut m = 0.0f64;
    for i in 0..n {
        let mut top = 0.0;
        let mut tail = 0.0;
        for r in 0..n {
            let v = view.u[(i, r)];
            let contrib = w[r] * v * v;
            if r < dd {
                top += contrib;
            } else {
                tail += contrib;
            }
        }
        let p = probs[i].max(1e-300);
        m = m.max(top / p).max(tail / p);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{kernel_matrix, Kernel};
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind};

    fn uniform_probs(n: usize) -> Vec<f64> {
        vec![1.0 / n as f64; n]
    }

    #[test]
    fn d_delta_and_statdim_monotone() {
        let mut rng = Pcg64::seed(141);
        let x = Matrix::from_fn(40, 2, |_, _| rng.uniform());
        let k = kernel_matrix(&Kernel::gaussian(0.5), &x);
        let view = SpectralView::new(&k);
        assert!(view.d_delta(1e-6) >= view.d_delta(1e-2));
        assert!(view.stat_dim(1e-6) >= view.stat_dim(1e-2));
        assert!(view.stat_dim(1e-3) <= 40.0);
    }

    #[test]
    fn identity_sketch_is_k_satisfiable() {
        // S = I (d = n) preserves everything: distortion 0, tail bounded by
        // the spectrum itself.
        let mut rng = Pcg64::seed(142);
        let x = Matrix::from_fn(20, 2, |_, _| rng.uniform());
        let k = kernel_matrix(&Kernel::gaussian(0.7), &x);
        let view = SpectralView::new(&k);
        let s = Sketch::Dense(Matrix::eye(20));
        let delta = 1e-3;
        let rep = k_satisfiability(&view, &s, delta);
        assert!(rep.top_distortion < 1e-6, "{}", rep.top_distortion);
        // tail norm = ‖Σ₂^{1/2}‖ = √σ_{d_δ+1} ≤ √δ
        assert!(rep.cond2, "tail={} vs √δ={}", rep.tail_norm, rep.sqrt_delta);
    }

    #[test]
    fn gaussian_distorts_top_eigenspace_less_than_nystrom_on_incoherent_data() {
        // two-cluster construction from paper §3.2: high incoherence makes
        // plain Nyström distort the top eigenspace far more than a Gaussian
        // sketch at the same d; accumulation with medium m sits in between,
        // close to Gaussian.
        // 2 far points out of 80 put an eigendirection (σ ≈ c/n = 0.025 >
        // δ = 0.02) almost entirely on two coordinates: uniform Nyström
        // misses both with probability (1−2/80)^d and then loses the whole
        // direction (distortion 1).
        let mut rng = Pcg64::seed(143);
        let n_big = 78;
        let n_small = 2;
        let n = n_big + n_small;
        let x = Matrix::from_fn(n, 2, |i, _| {
            if i < n_big {
                2.0 * rng.uniform()
            } else {
                30.0 + 0.05 * rng.uniform()
            }
        });
        let k = kernel_matrix(&Kernel::gaussian(1.0), &x);
        let view = SpectralView::new(&k);
        let delta = 0.02;
        let d = 60;
        let trials = 8;
        let mean_distortion = |kind: SketchKind| -> f64 {
            let mut rng = Pcg64::seed(144);
            (0..trials)
                .map(|_| {
                    let s = SketchBuilder::new(kind.clone()).build(n, d, &mut rng);
                    k_satisfiability(&view, &s, delta).top_distortion
                })
                .sum::<f64>()
                / trials as f64
        };
        let nys = mean_distortion(SketchKind::Nystrom);
        let accum = mean_distortion(SketchKind::Accumulation { m: 8 });
        let gauss = mean_distortion(SketchKind::Gaussian);
        assert!(
            gauss < 0.7 * nys,
            "gaussian distortion {gauss} should be well below nystrom {nys}"
        );
        assert!(
            accum < 0.8 * nys,
            "accumulation m=8 distortion {accum} should be well below nystrom {nys}"
        );
    }

    #[test]
    fn incoherence_high_for_unbalanced_clusters_uniform_sampling() {
        // paper §3.2 example: uniform sampling on unbalanced bimodal data
        // → M of order n.
        let mut rng = Pcg64::seed(145);
        let n_big = 78;
        let n_small = 2;
        let n = n_big + n_small;
        let x = Matrix::from_fn(n, 2, |i, _| {
            if i < n_big {
                2.0 * rng.uniform() // diffuse majority, smooth spectrum
            } else {
                30.0 + 0.05 * rng.uniform() // tight far minority
            }
        });
        let k = kernel_matrix(&Kernel::gaussian(1.0), &x);
        let view = SpectralView::new(&k);
        let delta = 0.02;
        let m_uniform = incoherence(&view, &uniform_probs(n), delta);
        // leverage-proportional sampling collapses M towards d_stat
        let scores = crate::leverage::exact_scores(&k, delta);
        let total: f64 = scores.iter().sum();
        let probs: Vec<f64> = scores.iter().map(|s| s / total).collect();
        let m_lev = incoherence(&view, &probs, delta);
        let d_stat = view.stat_dim(delta);
        assert!(
            m_uniform > 2.0 * m_lev,
            "uniform M = {m_uniform} should dwarf leverage M = {m_lev}"
        );
        // leverage sampling brings M to the order of d_stat (Theorem 8 rmk)
        assert!(
            m_lev < 3.0 * d_stat,
            "leverage M = {m_lev} should be O(d_stat = {d_stat})"
        );
        assert!(m_uniform > n as f64 / 4.0, "M = {m_uniform} vs n = {n}");
    }

    /// The partial-spectrum route reproduces the full-eigendecomposition
    /// report: identical U₁/U₂ split, and both operator norms to
    /// power-iteration tolerance (top_distortion depends only on the span
    /// of U₁; the tail Gram identity is exact).
    #[test]
    fn topk_route_matches_full_k_satisfiability() {
        let mut rng = Pcg64::seed(146);
        let n = 150;
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let k = kernel_matrix(&Kernel::gaussian(0.6), &x);
        let view = SpectralView::new(&k);
        // δ in the middle of the σ₅/σ₆ gap so d_δ is unambiguous
        let delta = 0.5 * (view.sigma[5] + view.sigma[6]);
        let mut srng = Pcg64::seed(147);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, 30, &mut srng);
        let full = k_satisfiability(&view, &s, delta);
        let part = k_satisfiability_topk(&k, &s, delta);
        assert_eq!(full.d_delta, part.d_delta, "U₁/U₂ split must agree");
        assert!(
            (full.top_distortion - part.top_distortion).abs()
                < 2e-3 * (1.0 + full.top_distortion),
            "distortion {} vs {}",
            full.top_distortion,
            part.top_distortion
        );
        // looser than top_distortion: the two routes power-iterate
        // *different* operators for the tail, so their convergence errors
        // are independent
        assert!(
            (full.tail_norm - part.tail_norm).abs() < 1e-2 * (1.0 + full.tail_norm),
            "tail {} vs {}",
            full.tail_norm,
            part.tail_norm
        );
        assert_eq!(full.sqrt_delta, part.sqrt_delta);
        // top-σ helper agrees with the dense spectrum
        let top = top_sigma(&k, 6);
        for j in 0..6 {
            assert!(
                (top[j] - view.sigma[j]).abs() < 1e-8 * (1.0 + view.sigma[j]),
                "σ{j}: {} vs {}",
                top[j],
                view.sigma[j]
            );
        }
    }

    /// The streamed route (Gram operator, no dense K anywhere) reproduces
    /// the dense top-k report: identical U₁/U₂ split, operator norms to
    /// power-iteration tolerance.
    #[test]
    fn streamed_route_matches_dense_topk() {
        let mut rng = Pcg64::seed(148);
        let n = 150;
        let x = Matrix::from_fn(n, 2, |_, _| rng.uniform());
        let kern = Kernel::gaussian(0.6);
        let k = kernel_matrix(&kern, &x);
        let view = SpectralView::new(&k);
        let delta = 0.5 * (view.sigma[5] + view.sigma[6]);
        let mut srng = Pcg64::seed(149);
        let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, 30, &mut srng);
        let dense = k_satisfiability_topk(&k, &s, delta);
        let op = crate::kernels::GramOperator::new(kern, &x);
        crate::kernels::assembly_guard::reset();
        let streamed = k_satisfiability_topk_streamed(&op, &s, delta);
        assert!(
            crate::kernels::assembly_guard::max_square() < n,
            "streamed k-sat must not assemble K"
        );
        assert_eq!(dense.d_delta, streamed.d_delta, "U₁/U₂ split must agree");
        assert!(
            (dense.top_distortion - streamed.top_distortion).abs()
                < 2e-3 * (1.0 + dense.top_distortion),
            "distortion {} vs {}",
            dense.top_distortion,
            streamed.top_distortion
        );
        assert!(
            (dense.tail_norm - streamed.tail_norm).abs() < 1e-2 * (1.0 + dense.tail_norm),
            "tail {} vs {}",
            dense.tail_norm,
            streamed.tail_norm
        );
        // streamed top-σ agrees with the dense spectrum too
        let top = top_sigma_streamed(&op, 6);
        for j in 0..6 {
            assert!(
                (top[j] - view.sigma[j]).abs() < 1e-8 * (1.0 + view.sigma[j]),
                "σ{j}: {} vs {}",
                top[j],
                view.sigma[j]
            );
        }
    }

    #[test]
    fn ksat_report_flags() {
        let rep = KSatReport {
            top_distortion: 0.4,
            tail_norm: 0.01,
            sqrt_delta: 0.1,
            d_delta: 3,
            cond1: true,
            cond2: true,
        };
        assert!(rep.satisfied());
    }
}
