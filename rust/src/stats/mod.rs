//! Statistical diagnostics from the paper's theory:
//! K-satisfiability (Definition 3), incoherence `M` (Theorem 8),
//! statistical dimension / `d_δ`, the error metrics used by every figure,
//! and the stopping rules driving the adaptive-m accumulation loop.

mod adapt;
mod errors;
mod ksat;

pub use adapt::{amm_error_proxy, rel_change, StoppingRule};
pub use errors::{in_sample_sq_error, mse, test_error};
pub use ksat::{
    incoherence, k_satisfiability, k_satisfiability_topk, k_satisfiability_topk_streamed,
    stat_dim, top_sigma, top_sigma_streamed, KSatReport, SpectralView,
};
