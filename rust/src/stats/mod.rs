//! Statistical diagnostics from the paper's theory:
//! K-satisfiability (Definition 3), incoherence `M` (Theorem 8),
//! statistical dimension / `d_δ`, and the error metrics used by every
//! figure.

mod errors;
mod ksat;

pub use errors::{in_sample_sq_error, mse, test_error};
pub use ksat::{incoherence, k_satisfiability, stat_dim, KSatReport, SpectralView};
