//! Sketched spectral clustering on the streamed Laplacian operator.
//!
//! The paper's abstract names **eigendecomposition in spectral
//! clustering** as the second headline application of accumulative
//! sub-sampling (next to matrix inversion in KRR). This module is that
//! workload, end to end, without ever materialising an `n×n` matrix:
//!
//! 1. **Graph + degrees** — the kernel similarity graph stays implicit in
//!    a [`LaplacianOperator`] over the row-tiled
//!    [`GramOperator`](crate::kernels::GramOperator); degrees `d = K·1`
//!    are accumulated in one streamed pass.
//! 2. **Embedding** — the bottom-`r` eigenvectors of
//!    `L_sym = I − D^{-1/2} K D^{-1/2}`, by one of
//!    * [`EmbedMethod::Operator`]: subspace iteration on the shifted
//!      operator `2I − L_sym` through
//!      [`partial_eigh_op`](crate::linalg::partial_eigh_op) — the
//!      "exact" streamed route, `O(tile·n + n·b)` memory;
//!    * [`EmbedMethod::Sketched`]: the accumulation-sketch pencil — the
//!      `d×d` eigenproblem of `N_S = NS (SᵀNS)⁻¹ SᵀN` over the
//!      normalized affinity `N`, reusing the KPCA `SᵀA²S` factorisation
//!      (`krr::kpca`); sparse sketches keep the support-column fast path
//!      (`O(n·|U|)` kernel evaluations);
//!    * [`EmbedMethod::Adaptive`]: the sketched pencil with the number
//!      of accumulated terms `m` discovered at runtime — an
//!      [`AccumSketch`] grows term by term and a
//!      [`StoppingRule`](crate::stats::StoppingRule) fires once the
//!      embedded subspace stabilises (the clustering analogue of
//!      `SketchedKrr::fit_adaptive`).
//! 3. **Rounding** — rows of the embedding are unit-normalised
//!    (Ng–Jordan–Weiss) and clustered by the deterministic Lloyd
//!    k-means in [`super::kmeans`].
//!
//! Every step is bitwise tile- and thread-invariant (streamed products,
//! elementwise scalings, fixed-order k-means accumulation), so a fit is
//! reproducible across machines and pool sizes. See DESIGN.md §7 for the
//! decision rule between the operator and pencil routes.

use super::kmeans::kmeans;
use super::laplacian::{LaplacianOperator, LAPLACIAN_SHIFT};
use crate::data::TileSource;
use crate::kernels::{GramOperator, Kernel};
use crate::krr::kpca_from_gram;
use crate::linalg::{eigh, matmul_at_b, partial_eigh_op, syrk_at_a, Matrix};
use crate::rng::Pcg64;
use crate::sketch::{AccumSketch, Sketch, SketchBuilder, SketchKind, SketchOps, SketchedGram};
use crate::stats::{amm_error_proxy, StoppingRule};

/// How the bottom-`r` Laplacian eigenvectors are computed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EmbedMethod {
    /// Streamed subspace iteration on `2I − L_sym` (no sketching): the
    /// reference route, exact up to the eigensolver's residual tolerance.
    Operator,
    /// Fixed accumulation sketch with `d` columns and `m` terms; the
    /// embedding comes from the `d×d` sketched pencil.
    Sketched {
        /// Sketch width (projection dimension).
        d: usize,
        /// Accumulated sub-sampling terms.
        m: usize,
    },
    /// Accumulation sketch grown term by term until the embedded
    /// subspace stabilises (relative change below `rel_tol`, see
    /// [`StoppingRule`](crate::stats::StoppingRule)) or `m_max` is hit.
    Adaptive {
        /// Sketch width (projection dimension).
        d: usize,
        /// Hard cap on accumulated terms.
        m_max: usize,
        /// Subspace-change stopping tolerance.
        rel_tol: f64,
    },
}

/// Options for [`SpectralClustering::fit`].
#[derive(Clone, Debug)]
pub struct SpectralOptions {
    /// Number of clusters.
    pub k: usize,
    /// Embedding dimension `r` (0 → `k`). Must be ≥ `k`; widths beyond
    /// `k` are useful for eigengap-based model selection (the
    /// coordinator's `cluster` job embeds once at `k_max + 1` and sweeps
    /// `k`).
    pub embed_dim: usize,
    /// Embedding route.
    pub method: EmbedMethod,
    /// Lloyd iteration cap for the final rounding step.
    pub kmeans_iters: usize,
    /// Gram-operator row-tile override (0 → default). A memory/perf
    /// knob only: results are bitwise unaffected.
    pub tile: usize,
}

impl Default for SpectralOptions {
    fn default() -> Self {
        SpectralOptions {
            k: 2,
            embed_dim: 0,
            method: EmbedMethod::Operator,
            kmeans_iters: 100,
            tile: 0,
        }
    }
}

/// A fitted spectral clustering.
#[derive(Clone, Debug)]
pub struct SpectralClustering {
    /// Cluster id per data row.
    pub labels: Vec<usize>,
    /// Spectral embedding (`n×r`): bottom-`r` (approximate) eigenvectors
    /// of `L_sym`, orthonormal columns, **not** row-normalised (the
    /// k-means rounding normalises its own copy).
    pub embedding: Matrix,
    /// Bottom-`r` eigenvalues of `L_sym`, ascending. Exact (to solver
    /// tolerance) for [`EmbedMethod::Operator`]; the sketched pencil's
    /// approximation otherwise.
    pub eigenvalues: Vec<f64>,
    /// Vertex degrees `d = K·1` from the streamed pass.
    pub degrees: Vec<f64>,
    /// Accumulated sketch terms actually used (`None` for the operator
    /// route; the stopping rule's choice for the adaptive route).
    pub chosen_m: Option<usize>,
    /// Lloyd iterations of the rounding step.
    pub kmeans_iters: usize,
    /// Final within-cluster sum of squares in the normalised embedding.
    pub inertia: f64,
}

impl SpectralClustering {
    /// Fit a spectral clustering of the rows of `x` under the kernel
    /// similarity graph. `rng` feeds sketch construction only — the
    /// [`EmbedMethod::Operator`] route draws nothing and is fully
    /// deterministic. Returns `None` when the sketched pencil is too
    /// ill-conditioned to factor at every attempted `m` (never happens
    /// on the operator route). `x` is any [`TileSource`]: with a
    /// file-backed source the whole fit — degrees, embedding, rounding —
    /// runs with `X` on disk, streaming `tile×p` feature panels
    /// (DESIGN.md §12); results are bitwise identical across backends.
    pub fn fit(
        kernel: Kernel,
        x: &dyn TileSource,
        opts: &SpectralOptions,
        rng: &mut Pcg64,
    ) -> Option<SpectralClustering> {
        let n = x.rows();
        let k = opts.k;
        assert!(k >= 1 && k <= n, "cluster: need 1 <= k <= n (k={k}, n={n})");
        let r = (if opts.embed_dim == 0 { k } else { opts.embed_dim }).min(n);
        assert!(r >= k, "cluster: embed_dim {r} must be >= k {k}");
        let mut gram = GramOperator::new(kernel, x);
        if opts.tile > 0 {
            gram = gram.with_tile(opts.tile);
        }
        let lap = LaplacianOperator::new(gram);
        let (embedding, eigenvalues, chosen_m) = match opts.method {
            EmbedMethod::Operator => {
                let pe = partial_eigh_op(&lap.shifted(LAPLACIAN_SHIFT), r);
                let vals: Vec<f64> = pe.w.iter().map(|&w| LAPLACIAN_SHIFT - w).collect();
                (pe.v, vals, None)
            }
            EmbedMethod::Sketched { d, m } => {
                assert!(r <= d, "cluster: sketch width {d} must be >= embed_dim {r}");
                let m = m.max(1);
                let s =
                    SketchBuilder::new(SketchKind::Accumulation { m }).build(n, d, rng);
                let (emb, vals) = pencil_embedding(&lap, &s, r)?;
                (emb, vals, Some(m))
            }
            EmbedMethod::Adaptive { d, m_max, rel_tol } => {
                assert!(r <= d, "cluster: sketch width {d} must be >= embed_dim {r}");
                adaptive_pencil_embedding(&lap, d, m_max.max(1), rel_tol, r, rng)?
            }
        };
        let points = row_normalize(&embedding, k.min(embedding.cols()));
        let km = kmeans(&points, k, opts.kmeans_iters);
        Some(SpectralClustering {
            labels: km.labels,
            embedding,
            eigenvalues,
            degrees: lap.degrees().to_vec(),
            chosen_m,
            kmeans_iters: km.iters,
            inertia: km.inertia,
        })
    }
}

/// Embedding from the sketched pencil over the normalized affinity
/// `N = D^{-1/2} K D^{-1/2}`: with `T = D^{-1/2} S`, the Grams the KPCA
/// pencil needs are `NS = D^{-1/2}·(K·T)` (support-column fast path for
/// sparse sketches), `SᵀNS = Tᵀ K T` and `SᵀN²S = (NS)ᵀ(NS)` — then
/// `krr`'s `L⁻¹(SᵀN²S)L⁻ᵀ` factorisation yields the top-`r`
/// eigenpairs of `N_S`, whose eigenvectors approximate `L_sym`'s bottom
/// eigenvectors. Returns `(embedding, bottom eigenvalues of L_sym)`.
fn pencil_embedding(
    lap: &LaplacianOperator,
    sketch: &Sketch,
    r: usize,
) -> Option<(Matrix, Vec<f64>)> {
    let n = lap.n();
    let d = sketch.d();
    let t = lap.normalized_sketch(sketch);
    let (kt, kernel_evals) = lap.gram().ks(&t);
    let mut ns = kt;
    lap.scale_rows(&mut ns); // NS = D^{-1/2} (K T)
    let mut stks = sketch.st_mat(&ns); // SᵀNS = TᵀKT
    stks.symmetrize();
    let stk2s = syrk_at_a(&ns); // SᵀN²S
    let gram = SketchedGram {
        ks: ns,
        stks,
        stk2s,
        kernel_evals,
    };
    let kp = kpca_from_gram(&gram, d, n, r)?;
    // kpca eigenvalues are of N_S/n; L_sym's bottom spectrum is 1 − λ(N)
    let vals: Vec<f64> = kp.eigenvalues.iter().map(|&v| 1.0 - v * n as f64).collect();
    Some((kp.components, vals))
}

/// Grow an [`AccumSketch`] term by term, recomputing the pencil
/// embedding after each append, until the embedded subspace stabilises.
/// The change metric is the normalised projector distance
/// `‖P_old − P_new‖_F / √(2r)` ([`subspace_change`]), fed to the same
/// [`StoppingRule`] (with the `√(n/(d·m))` accumulation-variance proxy)
/// that ends the adaptive KRR loop. A pencil that fails to factor at
/// some `m` (near-singular `SᵀNS` at low term counts) is skipped, not
/// fatal — more terms only improve conditioning.
fn adaptive_pencil_embedding(
    lap: &LaplacianOperator,
    d: usize,
    m_max: usize,
    rel_tol: f64,
    r: usize,
    rng: &mut Pcg64,
) -> Option<(Matrix, Vec<f64>, Option<usize>)> {
    let n = lap.n();
    let mut grower = AccumSketch::new(n, d);
    let mut rule = StoppingRule::new(rel_tol, 1).with_min_m(2);
    let mut prev: Option<Matrix> = None;
    let mut last: Option<(Matrix, Vec<f64>, usize)> = None;
    for m in 1..=m_max {
        grower.append_term(rng);
        let s = grower.as_sketch();
        let Some((emb, vals)) = pencil_embedding(lap, &s, r) else {
            continue;
        };
        let change = match &prev {
            Some(p) if p.cols() == emb.cols() => subspace_change(p, &emb),
            _ => f64::INFINITY,
        };
        prev = Some(emb.clone());
        last = Some((emb, vals, m));
        if rule.observe(m, change, amm_error_proxy(n, d, m)) {
            break;
        }
    }
    last.map(|(e, v, m)| (e, v, Some(m)))
}

/// Normalised projector distance `‖A Aᵀ − B Bᵀ‖_F / √(2r)` between two
/// `n×r` orthonormal bases — `0` for identical subspaces, `1` for
/// orthogonal ones; invariant to basis rotation (which is why it, and
/// not a column-wise difference, is the adaptive loop's change metric:
/// near-degenerate cluster eigenvalues make individual eigenvectors
/// spin freely while the subspace converges).
pub fn subspace_change(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.rows(), b.rows(), "subspace_change: row mismatch");
    assert_eq!(a.cols(), b.cols(), "subspace_change: rank mismatch");
    let r = a.cols();
    if r == 0 {
        return 0.0;
    }
    let g = matmul_at_b(a, b);
    let s: f64 = g.data().iter().map(|v| v * v).sum();
    ((2.0 * r as f64 - 2.0 * s).max(0.0) / (2.0 * r as f64)).sqrt()
}

/// Sine of the largest principal angle between two equal-rank
/// orthonormal bases: `√(1 − σ_min(AᵀB)²)`. This is the "subspace angle"
/// of the acceptance gate (streamed embedding vs dense reference).
pub fn max_principal_sine(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.rows(), b.rows(), "principal angle: row mismatch");
    assert_eq!(a.cols(), b.cols(), "principal angle: rank mismatch");
    if a.cols() == 0 {
        return 0.0;
    }
    let g = matmul_at_b(a, b);
    let mut gtg = matmul_at_b(&g, &g);
    gtg.symmetrize();
    let sigma_min_sq = eigh(&gtg).w[0].max(0.0);
    (1.0 - sigma_min_sq.min(1.0)).sqrt()
}

/// First `cols` columns of `emb` with each row scaled to unit norm
/// (Ng–Jordan–Weiss rounding); all-zero rows stay zero.
pub fn row_normalize(emb: &Matrix, cols: usize) -> Matrix {
    let n = emb.rows();
    let c = cols.min(emb.cols());
    let mut out = Matrix::zeros(n, c);
    for i in 0..n {
        let row = &emb.row(i)[..c];
        let nrm = row.iter().map(|v| v * v).sum::<f64>().sqrt();
        let inv = if nrm > 1e-300 { 1.0 / nrm } else { 0.0 };
        for (o, &v) in out.row_mut(i).iter_mut().zip(row.iter()) {
            *o = v * inv;
        }
    }
    out
}

/// Default accumulation-sketch width for a `k`-cluster embedding of
/// rank `r` over `n` points: `max(4k, 32, r)` capped at `n`. One policy
/// shared by the coordinator's `cluster` job and the bench so they
/// always measure the same configuration.
pub fn default_sketch_width(k: usize, r: usize, n: usize) -> usize {
    (4 * k).max(32).max(r).min(n)
}

/// Cluster sizes under `k` clusters (labels outside `0..k` are a bug and
/// panic via the index).
pub fn cluster_sizes(labels: &[usize], k: usize) -> Vec<usize> {
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l] += 1;
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::adjusted_rand_index;
    use crate::cluster::laplacian::dense_shifted_laplacian;
    use crate::data::blobs;
    use crate::kernels::{assembly_guard, kernel_matrix, DEFAULT_TILE};
    use crate::linalg::partial_eigh;
    use crate::pool;

    /// Well-separated blobs: tight clusters far apart, wide-ish
    /// bandwidth → clean spectral gap after the k-th eigenvalue, so the
    /// operator route's subspace iteration converges without fallback.
    fn blob_setup(n: usize, seed: u64) -> (Kernel, Matrix, Vec<usize>, Pcg64) {
        let mut rng = Pcg64::seed(seed);
        let (x, truth) = blobs(n, 3, 6.0, 0.3, &mut rng);
        (Kernel::gaussian(1.5), x, truth, rng)
    }

    /// Acceptance: streamed operator embedding equals the dense-assembly
    /// reference — eigenvalues to 1e-9, subspace angle < 1e-6 at equal
    /// rank.
    #[test]
    fn operator_embedding_matches_dense_reference() {
        let (kern, x, _, mut rng) = blob_setup(160, 0x1201);
        let opts = SpectralOptions {
            k: 3,
            ..Default::default()
        };
        let fit = SpectralClustering::fit(kern, &x, &opts, &mut rng).unwrap();
        let k = kernel_matrix(&kern, &x);
        let (shifted, deg) = dense_shifted_laplacian(&k, LAPLACIAN_SHIFT);
        let pe = partial_eigh(&shifted, 3);
        for j in 0..3 {
            let dense_val = LAPLACIAN_SHIFT - pe.w[j];
            assert!(
                (fit.eigenvalues[j] - dense_val).abs() < 1e-9,
                "λ{j}: {} vs {}",
                fit.eigenvalues[j],
                dense_val
            );
            // bottom Laplacian eigenvalues of a connected graph: λ₁ ≈ 0
            assert!(fit.eigenvalues[j] > -1e-9 && fit.eigenvalues[j] < 2.0);
        }
        let sine = max_principal_sine(&fit.embedding, &pe.v);
        assert!(sine < 1e-6, "subspace angle sin = {sine}");
        for (a, b) in fit.degrees.iter().zip(deg.iter()) {
            assert!((a - b).abs() < 1e-9, "degrees {a} vs {b}");
        }
    }

    /// Acceptance: ARI ≥ 0.95 on well-separated blobs for the streamed
    /// operator route (and the fixed-m sketched route close behind).
    #[test]
    fn blobs_ari_meets_acceptance() {
        let (kern, x, truth, mut rng) = blob_setup(180, 0x1202);
        let fit = SpectralClustering::fit(
            kern,
            &x,
            &SpectralOptions {
                k: 3,
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap();
        let ari = adjusted_rand_index(&fit.labels, &truth);
        assert!(ari >= 0.95, "operator ARI {ari}");
        assert_eq!(cluster_sizes(&fit.labels, 3).iter().sum::<usize>(), 180);
        let sk = SpectralClustering::fit(
            kern,
            &x,
            &SpectralOptions {
                k: 3,
                method: EmbedMethod::Sketched { d: 24, m: 4 },
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap();
        let ari_sk = adjusted_rand_index(&sk.labels, &truth);
        assert!(ari_sk >= 0.9, "sketched ARI {ari_sk}");
        assert_eq!(sk.chosen_m, Some(4));
    }

    /// The whole clustering fit — operator route *and* sparse sketched
    /// pencil — never assembles an `n×n` matrix (the tentpole's memory
    /// contract, same guard as the Gram-operator pipeline test).
    #[test]
    fn fit_never_assembles_n_by_n() {
        let n = 150;
        let (kern, x, _, mut rng) = blob_setup(n, 0x1203);
        assembly_guard::reset();
        let _ = SpectralClustering::fit(
            kern,
            &x,
            &SpectralOptions {
                k: 3,
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap();
        let _ = SpectralClustering::fit(
            kern,
            &x,
            &SpectralOptions {
                k: 3,
                method: EmbedMethod::Adaptive {
                    d: 20,
                    m_max: 6,
                    rel_tol: 1e-3,
                },
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap();
        assert!(
            assembly_guard::max_square() < n,
            "cluster::fit assembled a square of size {} (n = {n})",
            assembly_guard::max_square()
        );
    }

    /// Determinism: labels, embedding and eigenvalues are bitwise
    /// identical across tile sizes and thread counts (operator route —
    /// no RNG involved at all).
    #[test]
    fn fit_bitwise_invariant_across_tiles_and_threads() {
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, _, mut rng) = blob_setup(150, 0x1204);
        let before = pool::num_threads();
        pool::set_num_threads(1);
        let fit_with = |tile: usize, rng: &mut Pcg64| {
            SpectralClustering::fit(
                kern,
                &x,
                &SpectralOptions {
                    k: 3,
                    tile,
                    ..Default::default()
                },
                rng,
            )
            .unwrap()
        };
        let reference = fit_with(0, &mut rng);
        for &tile in &[1usize, DEFAULT_TILE, 150] {
            for &threads in &[1usize, 4] {
                pool::set_num_threads(threads);
                let got = fit_with(tile, &mut rng);
                assert_eq!(got.labels, reference.labels, "tile={tile} threads={threads}");
                assert_eq!(
                    got.embedding.data(),
                    reference.embedding.data(),
                    "embedding tile={tile} threads={threads}"
                );
                assert_eq!(
                    got.eigenvalues, reference.eigenvalues,
                    "eigenvalues tile={tile} threads={threads}"
                );
            }
        }
        pool::set_num_threads(before);
    }

    /// With the identity sketch (`d = n`) the pencil is exact: its
    /// embedding must match the operator route's eigenvalues and span.
    #[test]
    fn identity_sketch_pencil_recovers_exact_bottom_spectrum() {
        let (kern, x, _, _) = blob_setup(90, 0x1205);
        let lap = LaplacianOperator::new(GramOperator::new(kern, &x));
        let s = Sketch::Dense(Matrix::eye(90));
        let (emb, vals) = pencil_embedding(&lap, &s, 3).unwrap();
        let k = kernel_matrix(&kern, &x);
        let (shifted, _) = dense_shifted_laplacian(&k, LAPLACIAN_SHIFT);
        let pe = partial_eigh(&shifted, 3);
        for j in 0..3 {
            let want = LAPLACIAN_SHIFT - pe.w[j];
            assert!(
                (vals[j] - want).abs() < 1e-6,
                "pencil λ{j}: {} vs {}",
                vals[j],
                want
            );
        }
        let sine = max_principal_sine(&emb, &pe.v);
        assert!(sine < 1e-5, "identity-pencil subspace sin = {sine}");
    }

    /// Adaptive growth: the stopping rule picks an `m` within bounds,
    /// a disabled tolerance runs to `m_max`, and the result still
    /// clusters the blobs correctly.
    #[test]
    fn adaptive_pencil_chooses_m_and_clusters() {
        let (kern, x, truth, mut rng) = blob_setup(150, 0x1206);
        let opts = SpectralOptions {
            k: 3,
            method: EmbedMethod::Adaptive {
                d: 24,
                m_max: 8,
                rel_tol: 5e-2,
            },
            ..Default::default()
        };
        let fit = SpectralClustering::fit(kern, &x, &opts, &mut rng).unwrap();
        let m = fit.chosen_m.expect("adaptive fit reports chosen m");
        assert!((1..=8).contains(&m), "chosen m = {m}");
        let ari = adjusted_rand_index(&fit.labels, &truth);
        assert!(ari >= 0.9, "adaptive ARI {ari}");
        // disabled tolerance → the rule never fires early
        let sweep = SpectralClustering::fit(
            kern,
            &x,
            &SpectralOptions {
                k: 3,
                method: EmbedMethod::Adaptive {
                    d: 24,
                    m_max: 5,
                    rel_tol: -1.0,
                },
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap();
        assert_eq!(sweep.chosen_m, Some(5));
    }

    #[test]
    fn helpers_subspace_and_normalize() {
        let mut rng = Pcg64::seed(0x1207);
        let a = {
            // orthonormalise a random 30×3 block via its thin pencil
            let m = Matrix::from_fn(30, 3, |_, _| rng.normal());
            let g = eigh(&{
                let mut s = matmul_at_b(&m, &m);
                s.symmetrize();
                s
            });
            // whiten: A·G·Λ^{-1/2}
            let mut out = Matrix::zeros(30, 3);
            for i in 0..30 {
                for j in 0..3 {
                    let mut acc = 0.0;
                    for l in 0..3 {
                        acc += m[(i, l)] * g.v[(l, j)];
                    }
                    out[(i, j)] = acc / g.w[j].sqrt();
                }
            }
            out
        };
        assert!(subspace_change(&a, &a) < 1e-10);
        assert!(max_principal_sine(&a, &a) < 1e-6);
        let norm = row_normalize(&a, 3);
        for i in 0..30 {
            let n2: f64 = norm.row(i).iter().map(|v| v * v).sum();
            assert!((n2 - 1.0).abs() < 1e-9, "row {i} norm² {n2}");
        }
        assert_eq!(cluster_sizes(&[0, 1, 1, 2], 3), vec![1, 2, 1]);
    }
}
