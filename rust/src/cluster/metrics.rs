//! Clustering agreement metrics.
//!
//! The adjusted Rand index (ARI) is the acceptance metric for the
//! spectral-clustering workload (EXPERIMENTS.md §Clustering): it counts
//! pair-assignment agreements between two labelings, corrected for
//! chance, so it is invariant to label permutation — exactly what a
//! clustering comparison needs (k-means label ids are arbitrary).

/// Adjusted Rand index between two labelings of the same points.
///
/// `1.0` = identical partitions (up to label permutation), `≈ 0` =
/// agreement at chance level, negative = worse than chance. Degenerate
/// inputs where the correction denominator vanishes (e.g. both sides one
/// single cluster, or both all-singletons) are perfect agreements of
/// trivial partitions and return `1.0` by convention.
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len(), "ari: labelings must cover the same points");
    let n = a.len();
    if n <= 1 {
        return 1.0;
    }
    let ka = a.iter().max().map_or(0, |&m| m + 1);
    let kb = b.iter().max().map_or(0, |&m| m + 1);
    // contingency table + marginals
    let mut table = vec![0u64; ka * kb];
    let mut rows = vec![0u64; ka];
    let mut cols = vec![0u64; kb];
    for (&ai, &bi) in a.iter().zip(b.iter()) {
        table[ai * kb + bi] += 1;
        rows[ai] += 1;
        cols[bi] += 1;
    }
    let comb2 = |x: u64| -> f64 { (x as f64) * (x as f64 - 1.0) / 2.0 };
    let index: f64 = table.iter().map(|&c| comb2(c)).sum();
    let sum_rows: f64 = rows.iter().map(|&c| comb2(c)).sum();
    let sum_cols: f64 = cols.iter().map(|&c| comb2(c)).sum();
    let total = comb2(n as u64);
    let expected = sum_rows * sum_cols / total;
    let max_index = 0.5 * (sum_rows + sum_cols);
    let denom = max_index - expected;
    if denom.abs() < 1e-12 {
        return 1.0;
    }
    (index - expected) / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_and_permuted_labelings_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert_eq!(adjusted_rand_index(&a, &a), 1.0);
        // permuting the label ids must not change the score
        let b = vec![2, 2, 0, 0, 1, 1];
        assert_eq!(adjusted_rand_index(&a, &b), 1.0);
    }

    #[test]
    fn independent_labelings_score_near_zero() {
        // a checkerboard split vs a half split on 40 points: every pair
        // relation is as often preserved as broken
        let a: Vec<usize> = (0..40).map(|i| i % 2).collect();
        let b: Vec<usize> = (0..40).map(|i| (i / 20) % 2).collect();
        let s = adjusted_rand_index(&a, &b);
        assert!(s.abs() < 0.1, "chance-level ARI, got {s}");
    }

    #[test]
    fn partial_agreement_is_between() {
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 1, 1];
        let s = adjusted_rand_index(&a, &b);
        assert!(s > 0.0 && s < 1.0, "partial ARI, got {s}");
    }

    #[test]
    fn degenerate_partitions() {
        // both one cluster: denominator 0 → 1.0 by convention
        assert_eq!(adjusted_rand_index(&[0, 0, 0], &[0, 0, 0]), 1.0);
        // all singletons on both sides: same convention
        assert_eq!(adjusted_rand_index(&[0, 1, 2], &[2, 1, 0]), 1.0);
        assert_eq!(adjusted_rand_index(&[], &[]), 1.0);
    }

    #[test]
    fn known_small_value() {
        // classic worked example: n=6, a = {0,0,0,1,1,1}, b = {0,0,1,1,2,2}
        // contingency [[2,1,0],[0,1,2]]; index = 2, sum_rows = 6,
        // sum_cols = 3, total = 15, expected = 1.2, max = 4.5
        let a = vec![0, 0, 0, 1, 1, 1];
        let b = vec![0, 0, 1, 1, 2, 2];
        let s = adjusted_rand_index(&a, &b);
        let want = (2.0 - 1.2) / (4.5 - 1.2);
        assert!((s - want).abs() < 1e-12, "{s} vs {want}");
    }
}
