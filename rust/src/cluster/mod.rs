//! Sketched spectral clustering — the paper's second headline
//! application (abstract: "matrix inversion in kernel ridge regression
//! **and eigendecomposition in spectral clustering**"), built on the
//! streamed operator infrastructure:
//!
//! * [`laplacian`] — [`LaplacianOperator`]: the normalized graph
//!   Laplacian `L_sym = I − D^{-1/2} K D^{-1/2}` kept implicit over the
//!   row-tiled `kernels::GramOperator` (degrees in one streamed pass,
//!   bottom-k eigenpairs via the `2I − L_sym` shift trick through
//!   `linalg::partial_eigh_op`).
//! * [`spectral`] — [`SpectralClustering::fit`]: embedding (operator
//!   iteration, fixed accumulation-sketch pencil, or adaptive-m pencil
//!   with a `stats::StoppingRule`), Ng–Jordan–Weiss rounding, labels.
//! * [`kmeans`] — deterministic Lloyd k-means (derandomised k-means++
//!   seeding, per-row fixed-order accumulation) so the whole pipeline is
//!   bitwise tile- and thread-invariant.
//! * [`metrics`] — the adjusted Rand index, the workload's acceptance
//!   metric.
//!
//! Peak memory of a fit is `O(tile·n + n·k)` — no `n×n` object is ever
//! materialised (enforced by `kernels::assembly_guard` tests here and in
//! the pipeline test). The coordinator exposes the workload as the
//! `cluster` TCP job kind; `bench cluster` emits `BENCH_cluster.json`
//! (streamed vs dense Laplacian, peak RSS, ARI). See DESIGN.md §7 and
//! EXPERIMENTS.md §Clustering.

pub mod kmeans;
pub mod laplacian;
pub mod metrics;
pub mod spectral;

pub use kmeans::{kmeans as lloyd_kmeans, KmeansFit};
pub use laplacian::{
    dense_shifted_laplacian, LaplacianOperator, ShiftedLaplacian, LAPLACIAN_SHIFT,
};
pub use metrics::adjusted_rand_index;
pub use spectral::{
    cluster_sizes, default_sketch_width, max_principal_sine, row_normalize, subspace_change,
    EmbedMethod, SpectralClustering, SpectralOptions,
};
