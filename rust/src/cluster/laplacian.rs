//! Streamed normalized-Laplacian operator.
//!
//! Spectral clustering needs the bottom eigenvectors of the normalized
//! graph Laplacian `L_sym = I − D^{-1/2} K D^{-1/2}` of the kernel
//! similarity graph — an `n×n` object the streamed pipeline must never
//! materialise. [`LaplacianOperator`] wraps the row-tiled
//! [`GramOperator`]: the degree vector `d = K·1` is accumulated in **one
//! streamed pass** at construction, and every Laplacian action is then
//! two row scalings around a streamed `K·B` product:
//!
//! ```text
//!   L_sym·B = B − D^{-1/2} K (D^{-1/2} B)
//! ```
//!
//! so peak memory stays `O(tile·n + n·b)` — the Gram operator's tile
//! panel plus the thin block.
//!
//! # Bottom-k via the shift trick
//!
//! The subspace iteration behind
//! [`partial_eigh_op`](crate::linalg::partial_eigh_op) converges to the
//! **top** of a spectrum, and `L_sym`'s spectrum lies in `[0, 2]`. The
//! bottom-k pairs are therefore extracted from the shifted operator
//! `c·I − L_sym` with `c = 2`: it is PSD, its top-k eigenvectors are
//! exactly `L_sym`'s bottom-k, and eigenvalues map back as
//! `λ(L_sym) = c − λ(shifted)`. [`ShiftedLaplacian`] implements
//! [`SymOp`] so the partial eigensolver drives it directly (DESIGN.md
//! §7).
//!
//! # Determinism
//!
//! Degrees come from the Gram operator's `K·1` (bitwise tile- and
//! thread-invariant by the operator's fixed accumulation schedule), and
//! the scalings are elementwise — so every Laplacian product, and hence
//! the whole spectral embedding, inherits the pipeline's bitwise
//! invariance across tile sizes and thread counts.

use crate::kernels::GramOperator;
use crate::linalg::{Matrix, SymOp};
use crate::sketch::{Sketch, SparseSketch};

/// The shift constant `c` for the bottom-k trick: `spec(L_sym) ⊆ [0, 2]`
/// makes `2I − L_sym = I + D^{-1/2} K D^{-1/2}` positive semi-definite.
pub const LAPLACIAN_SHIFT: f64 = 2.0;

/// Implicit normalized Laplacian of the kernel similarity graph over the
/// rows of the wrapped operator's data. Never materialises `K` or `L`.
#[derive(Clone, Debug)]
pub struct LaplacianOperator<'a> {
    gram: GramOperator<'a>,
    /// Degrees `d_i = Σⱼ K[i,j]` (one streamed pass at construction).
    degrees: Vec<f64>,
    /// `1/√d_i`, precomputed for the row scalings.
    inv_sqrt_deg: Vec<f64>,
}

impl<'a> LaplacianOperator<'a> {
    /// Build the Laplacian view of a Gram operator, accumulating the
    /// degree vector `d = K·1` in a single streamed pass. Requires all
    /// degrees strictly positive (always true for strictly positive
    /// kernels like the Gaussian, whose diagonal alone contributes 1).
    pub fn new(gram: GramOperator<'a>) -> LaplacianOperator<'a> {
        let ones = vec![1.0; gram.n()];
        let degrees = gram.matvec(&ones);
        let inv_sqrt_deg: Vec<f64> = degrees
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                assert!(
                    d > 0.0,
                    "laplacian: non-positive degree {d} at row {i} (disconnected vertex)"
                );
                1.0 / d.sqrt()
            })
            .collect();
        LaplacianOperator {
            gram,
            degrees,
            inv_sqrt_deg,
        }
    }

    /// Number of graph vertices `n`.
    pub fn n(&self) -> usize {
        self.gram.n()
    }

    /// Degree vector `d = K·1`.
    pub fn degrees(&self) -> &[f64] {
        &self.degrees
    }

    /// The wrapped Gram operator.
    pub fn gram(&self) -> &GramOperator<'a> {
        &self.gram
    }

    /// Scale row `i` of `b` by `1/√d_i`, in place (the `D^{-1/2}·B`
    /// half-step; crate-visible for the sketched-pencil path).
    pub(crate) fn scale_rows(&self, b: &mut Matrix) {
        for (i, &s) in self.inv_sqrt_deg.iter().enumerate() {
            for v in b.row_mut(i).iter_mut() {
                *v *= s;
            }
        }
    }

    /// Normalized-affinity action `N·B = D^{-1/2} K (D^{-1/2} B)` — one
    /// streamed `K·B` between two elementwise row scalings.
    pub fn apply_norm_affinity(&self, b: &Matrix) -> Matrix {
        assert_eq!(b.rows(), self.n(), "laplacian: N·B row mismatch");
        let mut scaled = b.clone();
        self.scale_rows(&mut scaled);
        let mut out = self.gram.matmul(&scaled);
        self.scale_rows(&mut out);
        out
    }

    /// Normalized-Laplacian action `L_sym·B = B − N·B`, streamed.
    pub fn apply_lsym(&self, b: &Matrix) -> Matrix {
        let nb = self.apply_norm_affinity(b);
        let mut out = b.clone();
        out.axpy(-1.0, &nb);
        out
    }

    /// The shifted operator `c·I − L_sym` (use
    /// [`LAPLACIAN_SHIFT`] for the PSD bottom-k extraction).
    pub fn shifted(&self, c: f64) -> ShiftedLaplacian<'_, 'a> {
        ShiftedLaplacian { lap: self, c }
    }

    /// `D^{-1/2}·S` as a sketch of the same kind: the degree-normalised
    /// sketch `T` with which every sketched-pencil Gram over `N` is a
    /// plain sketched Gram over `K` (`SᵀNS = TᵀKT`,
    /// `N·S = D^{-1/2}·K·T`). Sparse sketches stay sparse — only the
    /// stored weights change — so the support-column fast path (and its
    /// `O(n·|U|)` kernel-evaluation count) is preserved.
    pub fn normalized_sketch(&self, s: &Sketch) -> Sketch {
        match s {
            Sketch::Sparse(sp) => {
                let cols: Vec<Vec<(usize, f64)>> = (0..sp.d())
                    .map(|j| {
                        sp.col(j)
                            .iter()
                            .map(|&(i, w)| (i, w * self.inv_sqrt_deg[i]))
                            .collect()
                    })
                    .collect();
                Sketch::Sparse(SparseSketch::new(self.n(), cols))
            }
            Sketch::Dense(m) => {
                let mut t = m.clone();
                self.scale_rows(&mut t);
                Sketch::Dense(t)
            }
        }
    }
}

/// `c·I − L_sym` as a [`SymOp`]: the operator
/// [`partial_eigh_op`](crate::linalg::partial_eigh_op) iterates to get
/// the bottom-k Laplacian eigenpairs without assembling anything `n×n`.
/// The [`materialize`](SymOp::materialize) escape hatch (dense-fallback
/// paths of the partial eigensolver only: small `n`, oversized block, or
/// a stalled iteration) assembles `K` once and is the one route back to
/// `O(n²)` memory — observable via `kernels::assembly_guard`, exactly
/// like the Gram operator's own fallback.
#[derive(Clone, Debug)]
pub struct ShiftedLaplacian<'l, 'a> {
    lap: &'l LaplacianOperator<'a>,
    c: f64,
}

impl SymOp for ShiftedLaplacian<'_, '_> {
    fn dim(&self) -> usize {
        self.lap.n()
    }

    /// `(c·I − L_sym)·B = (c−1)·B + N·B`.
    fn apply(&self, b: &Matrix) -> Matrix {
        let mut out = self.lap.apply_norm_affinity(b);
        out.axpy(self.c - 1.0, b);
        out
    }

    fn materialize(&self) -> Matrix {
        // one dense-assembly implementation for both the fallback and
        // the test/bench reference (degrees from dense row sums equal
        // the streamed pass — pinned by streamed_lsym_matches_dense)
        dense_shifted_laplacian(&self.lap.gram.materialize(), self.c).0
    }
}

/// Dense reference: `(c·I − L_sym, degrees)` from an already-assembled
/// kernel matrix. Used by the streamed-vs-dense equality tests and the
/// `BENCH_cluster` dense comparator — **not** by any streamed path.
pub fn dense_shifted_laplacian(k: &Matrix, c: f64) -> (Matrix, Vec<f64>) {
    let n = k.rows();
    assert_eq!(n, k.cols(), "dense_shifted_laplacian: square required");
    let degrees: Vec<f64> = (0..n).map(|i| k.row(i).iter().sum()).collect();
    let isd: Vec<f64> = degrees
        .iter()
        .map(|&d| {
            assert!(d > 0.0, "dense laplacian: non-positive degree {d}");
            1.0 / d.sqrt()
        })
        .collect();
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            m[(i, j)] = isd[i] * k[(i, j)] * isd[j];
        }
        m[(i, i)] += c - 1.0;
    }
    m.symmetrize();
    (m, degrees)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{kernel_matrix, Kernel, DEFAULT_TILE};
    use crate::linalg::matmul;
    use crate::pool;
    use crate::rng::Pcg64;
    use crate::sketch::{SketchBuilder, SketchKind, SketchOps};

    fn setup(n: usize, seed: u64) -> (Kernel, Matrix, Pcg64) {
        let mut rng = Pcg64::seed(seed);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        (Kernel::gaussian(0.9), x, rng)
    }

    /// Degrees from the streamed pass equal dense row sums, and the
    /// streamed `L_sym·B` equals the dense-assembled reference.
    #[test]
    fn streamed_lsym_matches_dense() {
        for &n in &[40usize, 250] {
            let (kern, x, mut rng) = setup(n, 0x1101);
            let b = Matrix::from_fn(n, 5, |_, _| rng.normal());
            let k = kernel_matrix(&kern, &x);
            let (shifted_dense, deg_dense) = dense_shifted_laplacian(&k, LAPLACIAN_SHIFT);
            let gram = GramOperator::new(kern, &x);
            let lap = LaplacianOperator::new(gram);
            for i in 0..n {
                assert!(
                    (lap.degrees()[i] - deg_dense[i]).abs() < 1e-10 * n as f64,
                    "degree {i}: {} vs {}",
                    lap.degrees()[i],
                    deg_dense[i]
                );
            }
            // dense L_sym·B = (c·B − shifted_dense·B) at c = LAPLACIAN_SHIFT
            let sd_b = matmul(&shifted_dense, &b);
            let streamed = lap.apply_lsym(&b);
            for i in 0..n {
                for j in 0..5 {
                    let dense_val = LAPLACIAN_SHIFT * b[(i, j)] - sd_b[(i, j)];
                    assert!(
                        (streamed[(i, j)] - dense_val).abs() < 1e-9 * n as f64,
                        "L·B ({i},{j}) n={n}: {} vs {}",
                        streamed[(i, j)],
                        dense_val
                    );
                }
            }
            // shifted apply agrees with the dense shifted matrix too
            let shifted_streamed = lap.shifted(LAPLACIAN_SHIFT).apply(&b);
            for i in 0..n {
                for j in 0..5 {
                    assert!(
                        (shifted_streamed[(i, j)] - sd_b[(i, j)]).abs() < 1e-9 * n as f64,
                        "(cI−L)·B ({i},{j})"
                    );
                }
            }
        }
    }

    /// The determinism contract: degrees and Laplacian products are
    /// bitwise identical across tile sizes and thread counts.
    #[test]
    fn degrees_and_products_bitwise_invariant() {
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (kern, x, mut rng) = setup(201, 0x1102);
        let b = Matrix::from_fn(201, 4, |_, _| rng.normal());
        let before = pool::num_threads();
        pool::set_num_threads(1);
        let reference = LaplacianOperator::new(GramOperator::new(kern, &x));
        let ref_apply = reference.apply_lsym(&b);
        for &tile in &[1usize, 37, DEFAULT_TILE, 201] {
            for &threads in &[1usize, 4] {
                pool::set_num_threads(threads);
                let lap = LaplacianOperator::new(GramOperator::new(kern, &x).with_tile(tile));
                assert_eq!(
                    lap.degrees(),
                    reference.degrees(),
                    "degrees tile={tile} threads={threads}"
                );
                let got = lap.apply_lsym(&b);
                assert_eq!(
                    got.data(),
                    ref_apply.data(),
                    "L·B tile={tile} threads={threads}"
                );
            }
        }
        pool::set_num_threads(before);
    }

    /// Row sums of `L_sym` are *not* zero in general, but `L_sym` must
    /// annihilate the √degree vector: `L_sym·(D^{1/2}·1) = 0` — the
    /// defining property of the normalized Laplacian's bottom eigenpair.
    #[test]
    fn sqrt_degree_vector_is_null_vector() {
        let (kern, x, _) = setup(80, 0x1103);
        let lap = LaplacianOperator::new(GramOperator::new(kern, &x));
        let v = Matrix::from_fn(80, 1, |i, _| lap.degrees()[i].sqrt());
        let lv = lap.apply_lsym(&v);
        let scale = lap.degrees().iter().fold(0.0f64, |m, &d| m.max(d.sqrt()));
        for i in 0..80 {
            assert!(
                lv[(i, 0)].abs() < 1e-10 * scale,
                "null vector residual {} at {i}",
                lv[(i, 0)]
            );
        }
    }

    /// `normalized_sketch` really is `D^{-1/2}·S` for sparse and dense
    /// sketches alike (checked through densification).
    #[test]
    fn normalized_sketch_matches_dense_scaling() {
        let (kern, x, mut rng) = setup(50, 0x1104);
        let lap = LaplacianOperator::new(GramOperator::new(kern, &x));
        for kind in [SketchKind::Accumulation { m: 3 }, SketchKind::Gaussian] {
            let s = SketchBuilder::new(kind).build(50, 7, &mut rng);
            let t = lap.normalized_sketch(&s);
            let sd = s.to_dense();
            let td = t.to_dense();
            for i in 0..50 {
                let isd = 1.0 / lap.degrees()[i].sqrt();
                for j in 0..7 {
                    assert!(
                        (td[(i, j)] - sd[(i, j)] * isd).abs() < 1e-14,
                        "T ({i},{j})"
                    );
                }
            }
        }
    }

    /// The dense `materialize` fallback agrees with the streamed apply.
    #[test]
    fn materialize_matches_streamed_apply() {
        let (kern, x, mut rng) = setup(60, 0x1105);
        let b = Matrix::from_fn(60, 3, |_, _| rng.normal());
        let lap = LaplacianOperator::new(GramOperator::new(kern, &x));
        let shifted = lap.shifted(LAPLACIAN_SHIFT);
        let dense = shifted.materialize();
        let want = matmul(&dense, &b);
        let got = shifted.apply(&b);
        for i in 0..60 {
            for j in 0..3 {
                assert!(
                    (got[(i, j)] - want[(i, j)]).abs() < 1e-10 * 60.0,
                    "materialize ({i},{j})"
                );
            }
        }
    }
}
