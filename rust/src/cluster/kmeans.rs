//! Deterministic Lloyd k-means over a spectral embedding.
//!
//! Spectral clustering's final step clusters the `n×k` embedding rows.
//! Randomised k-means++ would make the *whole* pipeline's output depend
//! on an RNG stream even though the embedding itself is deterministic
//! (bitwise tile- and thread-invariant, see `kernels::operator`), so this
//! implementation is deterministic end to end, in the same spirit as the
//! GEMM core's fixed accumulation schedules:
//!
//! * **seeding** is the derandomised k-means++ (farthest-point / maximin)
//!   rule: the first centre is the point farthest from the data mean,
//!   each next centre the point maximising the distance to its nearest
//!   chosen centre — the `D²` rule with the argmax replacing the random
//!   draw. Ties break to the lowest index.
//! * **assignment** is per-row independent (one owner per point, centres
//!   scanned in ascending order, ties to the lower centre id), so it can
//!   run on the worker pool and stay bitwise thread-invariant.
//! * **updates** accumulate centre sums serially in ascending row order —
//!   fixed FP grouping, whatever the thread count did during assignment.

use crate::linalg::Matrix;
use crate::pool;

/// Result of [`kmeans`].
#[derive(Clone, Debug)]
pub struct KmeansFit {
    /// Cluster id per input row.
    pub labels: Vec<usize>,
    /// Final centres (`k×p`).
    pub centers: Matrix,
    /// Within-cluster sum of squared distances at the final assignment.
    pub inertia: f64,
    /// Lloyd iterations run (assignment+update rounds).
    pub iters: usize,
}

/// Squared Euclidean distance between two rows.
fn sqd(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Derandomised k-means++ seeding: indices of `k` distinct rows.
fn seed_indices(points: &Matrix, k: usize) -> Vec<usize> {
    let (n, p) = (points.rows(), points.cols());
    // data mean (serial, ascending — fixed grouping)
    let mut mean = vec![0.0; p];
    for i in 0..n {
        for (m, v) in mean.iter_mut().zip(points.row(i).iter()) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut chosen = Vec::with_capacity(k);
    let mut best = (f64::NEG_INFINITY, 0usize);
    for i in 0..n {
        let d = sqd(points.row(i), &mean);
        if d > best.0 {
            best = (d, i);
        }
    }
    chosen.push(best.1);
    // min squared distance to the chosen set, updated incrementally
    let mut dist2: Vec<f64> = (0..n)
        .map(|i| sqd(points.row(i), points.row(chosen[0])))
        .collect();
    while chosen.len() < k {
        let mut far = (f64::NEG_INFINITY, 0usize);
        for (i, &d) in dist2.iter().enumerate() {
            if d > far.0 {
                far = (d, i);
            }
        }
        chosen.push(far.1);
        let c = *chosen.last().unwrap();
        for i in 0..n {
            let d = sqd(points.row(i), points.row(c));
            if d < dist2[i] {
                dist2[i] = d;
            }
        }
    }
    chosen
}

/// Deterministic Lloyd k-means (see the module docs for the determinism
/// contract). `k` must satisfy `1 ≤ k ≤ n`.
pub fn kmeans(points: &Matrix, k: usize, max_iters: usize) -> KmeansFit {
    let (n, p) = (points.rows(), points.cols());
    assert!(k >= 1 && k <= n, "kmeans: need 1 <= k <= n (k={k}, n={n})");
    let seeds = seed_indices(points, k);
    let mut centers = Matrix::zeros(k, p);
    for (c, &i) in seeds.iter().enumerate() {
        centers.row_mut(c).copy_from_slice(points.row(i));
    }
    let mut labels = vec![0usize; n];
    let mut iters = 0usize;
    for it in 0..max_iters.max(1) {
        iters = it + 1;
        // assignment: per-row independent, bitwise thread-invariant
        let assigned = {
            let centers = &centers;
            pool::parallel_map(n, |i| {
                let row = points.row(i);
                let mut best = (f64::INFINITY, 0usize);
                for c in 0..k {
                    let d = sqd(row, centers.row(c));
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                best.1
            })
        };
        let changed = assigned != labels;
        labels = assigned;
        if !changed && it > 0 {
            break;
        }
        // update: serial, ascending row order — fixed FP grouping
        let mut counts = vec![0usize; k];
        let mut sums = Matrix::zeros(k, p);
        for i in 0..n {
            counts[labels[i]] += 1;
            let srow = sums.row_mut(labels[i]);
            for (s, v) in srow.iter_mut().zip(points.row(i).iter()) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                for (cv, sv) in centers.row_mut(c).iter_mut().zip(sums.row(c).iter()) {
                    *cv = sv * inv;
                }
            } else {
                // deterministic empty-cluster rescue: move the centre to
                // the point farthest from its current centre
                let mut far = (f64::NEG_INFINITY, 0usize);
                for i in 0..n {
                    let d = sqd(points.row(i), centers.row(labels[i]));
                    if d > far.0 {
                        far = (d, i);
                    }
                }
                centers.row_mut(c).copy_from_slice(points.row(far.1));
            }
        }
    }
    let mut inertia = 0.0;
    for i in 0..n {
        inertia += sqd(points.row(i), centers.row(labels[i]));
    }
    KmeansFit {
        labels,
        centers,
        inertia,
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn perfect_split_zero_inertia() {
        let pts = Matrix::from_fn(40, 2, |i, _| if i % 2 == 0 { 0.0 } else { 5.0 });
        let fit = kmeans(&pts, 2, 50);
        assert!(fit.inertia < 1e-12, "inertia {}", fit.inertia);
        // both clusters used, labels follow the parity pattern
        assert_ne!(fit.labels[0], fit.labels[1]);
        for i in 2..40 {
            assert_eq!(fit.labels[i], fit.labels[i % 2]);
        }
    }

    #[test]
    fn deterministic_across_runs_and_thread_counts() {
        let _guard = pool::TEST_THREADS_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Pcg64::seed(0x5eed);
        let pts = Matrix::from_fn(120, 3, |_, _| rng.normal());
        let before = pool::num_threads();
        pool::set_num_threads(1);
        let a = kmeans(&pts, 4, 100);
        for &threads in &[1usize, 4] {
            pool::set_num_threads(threads);
            let b = kmeans(&pts, 4, 100);
            assert_eq!(a.labels, b.labels, "threads={threads}");
            assert_eq!(a.centers.data(), b.centers.data(), "threads={threads}");
            assert_eq!(a.inertia.to_bits(), b.inertia.to_bits(), "threads={threads}");
        }
        pool::set_num_threads(before);
    }

    #[test]
    fn seeding_picks_spread_points() {
        // three tight far-apart groups: maximin seeding must take one
        // point from each before Lloyd even starts
        let pts = Matrix::from_fn(30, 1, |i, _| match i % 3 {
            0 => 0.0 + i as f64 * 1e-4,
            1 => 100.0 + i as f64 * 1e-4,
            _ => -100.0 + i as f64 * 1e-4,
        });
        let seeds = seed_indices(&pts, 3);
        let groups: std::collections::HashSet<usize> = seeds.iter().map(|&i| i % 3).collect();
        assert_eq!(groups.len(), 3, "seeds {seeds:?} missed a group");
    }

    #[test]
    fn k_equals_one_and_k_equals_n() {
        let mut rng = Pcg64::seed(0x5eee);
        let pts = Matrix::from_fn(8, 2, |_, _| rng.normal());
        let one = kmeans(&pts, 1, 10);
        assert!(one.labels.iter().all(|&l| l == 0));
        let all = kmeans(&pts, 8, 10);
        // n distinct points, n centres → every cluster is a singleton
        let mut seen: Vec<usize> = all.labels.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 8);
        assert!(all.inertia < 1e-12);
    }
}
