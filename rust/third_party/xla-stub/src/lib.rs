//! Compile-time stand-in for the `xla` PJRT bindings.
//!
//! The offline build must resolve without registry access, but the
//! `runtime` layer of `accumkrr` should keep *type-checking* under
//! `--features xla` so it cannot rot silently. This crate mirrors exactly
//! the API surface `accumkrr::runtime` consumes; every entry point that
//! would touch a real PJRT plugin returns [`Error::StubRuntime`] instead.
//!
//! To execute artifacts for real, replace the path dependency in
//! `rust/Cargo.toml` with the published `xla` bindings — the signatures
//! here are kept call-compatible with that crate.

use std::fmt;

/// Error type matching the shape of the real bindings' error.
#[derive(Debug)]
pub enum Error {
    /// The stub was asked to perform real PJRT work.
    StubRuntime(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubRuntime(what) => write!(
                f,
                "{what}: built against the in-tree xla stub; swap the \
                 `xla` path dependency for the real bindings to run artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias used by the whole stub surface.
pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &'static str) -> Result<T> {
    Err(Error::StubRuntime(what))
}

/// PJRT client handle (stub: unconstructible at runtime).
pub struct PjRtClient(());

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable(());

/// A device buffer (stub).
pub struct PjRtBuffer(());

/// A host literal (stub: constructible, but all conversions fail).
#[derive(Clone)]
pub struct Literal(());

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

/// An XLA computation built from a proto (stub).
pub struct XlaComputation(());

impl PjRtClient {
    /// Create a CPU client. Always fails on the stub.
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    /// Platform name of the backing PJRT plugin.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation. Unreachable on the stub (no client exists).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs. Unreachable on the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }
}

impl PjRtBuffer {
    /// Device → host transfer. Unreachable on the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

impl HloModuleProto {
    /// Parse an HLO text file. Always fails on the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Scalar literal.
    pub fn scalar(_x: f32) -> Literal {
        Literal(())
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_entry_points_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "{msg}");
    }
}
