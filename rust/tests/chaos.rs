//! Chaos tests: the serving plane under a deterministic fault matrix.
//!
//! Every test opens by taking the global fault-scope lock
//! ([`fault::scoped`] / [`fault::locked`]) **before** any serving
//! activity, so the suite serializes even under the default
//! multi-threaded test harness — scoped triggers like `nth:1` count
//! hits process-wide and must not observe another test's traffic.
//!
//! `cargo test --test chaos` passes with the registry disarmed; CI
//! additionally runs it with `ACCUMKRR_FAULTS` arming io / panic /
//! numeric legs, which the [`fault::locked`] survival test exercises
//! against whatever the environment armed.

use accumkrr::coordinator::frame::{encode_frame, read_frame, write_frame};
use accumkrr::coordinator::state::{SamplingSpec, TrainRequest};
use accumkrr::coordinator::{
    BatcherConfig, Client, ClientConfig, DataSpec, ModelStore, ServerConfig, ServerHandle,
};
use accumkrr::data::{write_f64_file, write_f64_vec, CACHE_BUDGET_ENV};
use accumkrr::kernels::Kernel;
use accumkrr::krr::{AdaptiveOptions, SketchedKrr};
use accumkrr::linalg::{Matrix, Precision};
use accumkrr::rng::Pcg64;
use accumkrr::sketch::{Sampling, SketchBuilder, SketchKind};
use accumkrr::util::json::Json;
use accumkrr::util::{fault, ErrorKind};
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Train a small bimodal model (3 feature columns) into `store` under
/// `name` — same shape as tests/serving.rs' fixture.
fn train_into(store: &ModelStore, name: &str) {
    store
        .train(&TrainRequest {
            name: name.into(),
            dataset: "bimodal".into(),
            n: 150,
            kind: SketchKind::Accumulation { m: 3 },
            d: 10,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 5,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        })
        .unwrap();
}

fn store_with_model() -> Arc<ModelStore> {
    let store = Arc::new(ModelStore::new());
    train_into(&store, "m");
    store
}

fn start(store: Arc<ModelStore>, tweak: impl FnOnce(&mut ServerConfig)) -> ServerHandle {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    tweak(&mut cfg);
    ServerHandle::start(store, cfg).unwrap()
}

fn connect(h: &ServerHandle) -> TcpStream {
    let c = TcpStream::connect(h.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    c
}

/// Read framed replies until one matches the wanted id.
fn read_id(conn: &mut TcpStream, want: usize) -> Json {
    loop {
        let j = read_frame(conn).unwrap();
        if j.get("id").and_then(|v| v.as_usize()) == Some(want) {
            return j;
        }
    }
}

fn predict_req(id: usize, model: &str, rows: &[Vec<f64>]) -> Json {
    Json::obj(vec![
        ("id", Json::from(id)),
        ("method", Json::from("predict")),
        ("model", Json::from(model)),
        ("x", Json::Arr(rows.iter().map(|r| Json::nums(r)).collect())),
    ])
}

fn code_of(r: &Json) -> &str {
    r.get("err_code").and_then(|v| v.as_str()).unwrap_or("")
}

fn metrics_op(conn: &mut TcpStream, id: usize) -> Json {
    write_frame(
        conn,
        &Json::obj(vec![("id", Json::from(id)), ("method", Json::from("metrics"))]),
    )
    .unwrap();
    read_id(conn, id)
}

/// An injected `chol.downdate` failure in an adaptive fit is rescued by
/// the diag-jitter retry ladder: the fit succeeds and reports
/// `jitter_bumps >= 1` instead of degrading to a refactor or dying.
#[test]
fn downdate_fault_recovers_with_jitter_in_direct_fit() {
    let _g = fault::scoped("chol.downdate=nth:1");
    let store = ModelStore::new();
    let sm = store
        .train(&TrainRequest {
            name: "adm".into(),
            dataset: "bimodal".into(),
            n: 150,
            kind: SketchKind::Accumulation { m: 1 },
            d: 10,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 7,
            // rank_update_limit = MAX forces every round through the
            // incremental rank-update (and so the downdate) path
            adaptive: Some(AdaptiveOptions {
                m_max: 16,
                rel_tol: 0.05,
                rank_update_limit: Some(usize::MAX),
                ..Default::default()
            }),
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        })
        .expect("adaptive fit must survive an injected downdate failure");
    let rep = sm.model.report();
    assert!(rep.jitter_bumps >= 1, "recovery must be visible: {rep:?}");
    assert_eq!(fault::fired("chol.downdate"), 1, "nth:1 fires exactly once");
    assert!(
        fault::hits("chol.downdate") >= 2,
        "the retry must re-enter the downdate seam, hits={}",
        fault::hits("chol.downdate")
    );
}

/// Same recovery end to end over the wire: the framed train reply
/// carries `jitter_bumps` telemetry when the factorization was rescued.
#[test]
fn downdate_fault_surfaces_jitter_bumps_in_train_reply() {
    let _g = fault::scoped("chol.downdate=nth:1");
    let h = start(Arc::new(ModelStore::new()), |_| {});
    let mut conn = connect(&h);
    write_frame(
        &mut conn,
        &Json::obj(vec![
            ("id", Json::from(1usize)),
            ("method", Json::from("train")),
            ("name", Json::from("adm")),
            ("dataset", Json::from("bimodal")),
            ("n", Json::from(150usize)),
            ("sketch", Json::from("adaptive")),
            ("d", Json::from(10usize)),
            ("lambda", Json::Num(1e-3)),
            ("m_max", Json::from(16usize)),
            ("rel_tol", Json::Num(0.05)),
            ("seed", Json::from(7usize)),
            ("rank_update_limit", Json::from(1_000_000_000usize)),
        ]),
    )
    .unwrap();
    let r = read_id(&mut conn, 1);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    let bumps = r.get("jitter_bumps").and_then(|v| v.as_usize());
    assert!(bumps >= Some(1), "train reply must report the rescue: {r}");
    h.stop();
}

/// A worker panic during a batched predict is caught, fails only that
/// request with `internal`, and quarantines the model: later predicts
/// answer `model_unhealthy` without running the kernel, other models on
/// other connections keep serving, and a retrain heals the name.
#[test]
fn worker_panic_quarantines_model_until_retrain() {
    let _g = fault::scoped("worker.panic=nth:1");
    let store = store_with_model();
    train_into(&store, "healthy");
    let h = start(store, |_| {});
    let metrics = h.metrics();
    let mut conn = connect(&h);
    // first predict: the injected panic fails the batch, structured
    write_frame(&mut conn, &predict_req(1, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    let r = read_id(&mut conn, 1);
    assert_eq!(code_of(&r), ErrorKind::Internal.code(), "{r}");
    assert!(
        r.get("error").and_then(|v| v.as_str()).unwrap().contains("quarantined"),
        "{r}"
    );
    // the poisoned model is now fenced off before the batcher
    write_frame(&mut conn, &predict_req(2, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    let r = read_id(&mut conn, 2);
    assert_eq!(code_of(&r), ErrorKind::ModelUnhealthy.code(), "{r}");
    // no cross-poisoning: another model on another connection serves
    let mut other = connect(&h);
    write_frame(&mut other, &predict_req(3, "healthy", &[vec![0.5, 0.5, 0.5]])).unwrap();
    let r = read_id(&mut other, 3);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.quarantined.load(Ordering::Relaxed), 1);
    // retrain under the same name heals the quarantine
    write_frame(
        &mut conn,
        &Json::obj(vec![
            ("id", Json::from(4usize)),
            ("method", Json::from("train")),
            ("name", Json::from("m")),
            ("dataset", Json::from("bimodal")),
            ("n", Json::from(150usize)),
            ("sketch", Json::from("accum")),
            ("m", Json::from(3usize)),
            ("d", Json::from(10usize)),
            ("lambda", Json::Num(1e-3)),
            ("seed", Json::from(5usize)),
        ]),
    )
    .unwrap();
    assert_eq!(read_id(&mut conn, 4).get("ok"), Some(&Json::Bool(true)));
    write_frame(&mut conn, &predict_req(5, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    let r = read_id(&mut conn, 5);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "healed model must serve: {r}");
    h.stop();
}

/// `deadline_ms: 0` is answered `deadline_exceeded` by both the batcher
/// (predict) and the task pool (train) without spending any compute:
/// the GEMM row counter stays at zero.
#[test]
fn expired_deadline_answers_without_consuming_compute() {
    let _g = fault::scoped("");
    let h = start(store_with_model(), |_| {});
    let metrics = h.metrics();
    let mut conn = connect(&h);
    let mut pred = predict_req(1, "m", &[vec![0.1, 0.2, 0.3]]);
    if let Json::Obj(m) = &mut pred {
        m.insert("deadline_ms".into(), Json::from(0usize));
    }
    write_frame(&mut conn, &pred).unwrap();
    let r = read_id(&mut conn, 1);
    assert_eq!(code_of(&r), ErrorKind::DeadlineExceeded.code(), "{r}");
    write_frame(
        &mut conn,
        &Json::obj(vec![
            ("id", Json::from(2usize)),
            ("method", Json::from("train")),
            ("name", Json::from("late")),
            ("dataset", Json::from("bimodal")),
            ("n", Json::from(150usize)),
            ("deadline_ms", Json::from(0usize)),
        ]),
    )
    .unwrap();
    let r = read_id(&mut conn, 2);
    assert_eq!(code_of(&r), ErrorKind::DeadlineExceeded.code(), "{r}");
    assert!(metrics.deadline_expired.load(Ordering::Relaxed) >= 2);
    assert_eq!(metrics.queries.load(Ordering::Relaxed), 0, "no GEMM for expired work");
    // the taxonomy table in the metrics op agrees
    let m = metrics_op(&mut conn, 3);
    let dl = m
        .get("err_codes")
        .and_then(|c| c.get("deadline_exceeded"))
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!(dl >= 2.0, "{m}");
    h.stop();
}

/// A queued deadline trumps the batching policy: with a 5 s fixed batch
/// wait, a request carrying `deadline_ms` is flushed near its deadline
/// instead of idling out the full wait.
#[test]
fn deadline_forces_early_flush_under_long_fixed_wait() {
    let _g = fault::scoped("");
    let h = start(store_with_model(), |cfg| {
        cfg.batcher = BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_secs(5),
            adaptive: false,
        };
    });
    let mut conn = connect(&h);
    let mut pred = predict_req(1, "m", &[vec![0.1, 0.2, 0.3]]);
    if let Json::Obj(m) = &mut pred {
        m.insert("deadline_ms".into(), Json::from(400usize));
    }
    let t0 = Instant::now();
    write_frame(&mut conn, &pred).unwrap();
    let r = read_id(&mut conn, 1);
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(2500),
        "deadline must beat the 5s fixed wait, took {elapsed:?}"
    );
    // scheduling jitter may land the flush on either side of the
    // deadline — both outcomes are in-contract, sitting out 5 s is not
    if r.get("ok") != Some(&Json::Bool(true)) {
        assert_eq!(code_of(&r), ErrorKind::DeadlineExceeded.code(), "{r}");
    }
    h.stop();
}

/// An injected read fault mid-request behaves as a connection reset;
/// the retrying client reconnects and the call still succeeds.
#[test]
fn io_read_fault_is_retried_transparently_by_the_client() {
    let _g = fault::scoped("io.read=nth:1");
    let h = start(store_with_model(), |_| {});
    let mut c = Client::new(ClientConfig {
        addr: h.addr().to_string(),
        retries: 3,
        backoff: Duration::from_millis(2),
        seed: 11,
        legacy: false,
    });
    let r = c.call(&Json::obj(vec![("method", Json::from("ping"))])).unwrap();
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)), "{r}");
    let (attempts, retries) = c.stats();
    assert!(retries >= 1, "the killed connection must have been retried");
    assert!(attempts >= 2);
    let t0 = Instant::now();
    h.stop();
    assert!(t0.elapsed() < Duration::from_secs(2), "shutdown stays bounded");
}

/// An injected write fault drops a reply (broken pipe): only that
/// connection dies, the client retries through, and fresh connections
/// are unaffected.
#[test]
fn io_write_fault_drops_reply_but_not_the_server() {
    let _g = fault::scoped("io.write=nth:1");
    let h = start(store_with_model(), |_| {});
    let mut c = Client::new(ClientConfig {
        addr: h.addr().to_string(),
        retries: 3,
        backoff: Duration::from_millis(2),
        seed: 13,
        legacy: false,
    });
    let r = c.call(&Json::obj(vec![("method", Json::from("ping"))])).unwrap();
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)), "{r}");
    let (_, retries) = c.stats();
    assert!(retries >= 1, "the dropped reply must have been retried");
    // a raw connection opened after the fault is clean
    let mut conn = connect(&h);
    write_frame(&mut conn, &predict_req(1, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    assert_eq!(read_id(&mut conn, 1).get("ok"), Some(&Json::Bool(true)));
    h.stop();
}

/// An injected decode fault corrupts exactly one frame: the server
/// answers a structured `invalid_input` and the connection survives for
/// the next request.
#[test]
fn frame_decode_fault_degrades_to_structured_error() {
    let _g = fault::scoped("frame.decode=nth:1");
    let h = start(store_with_model(), |_| {});
    let metrics = h.metrics();
    let mut conn = connect(&h);
    write_frame(&mut conn, &Json::obj(vec![("method", Json::from("ping"))])).unwrap();
    let r = read_frame(&mut conn).unwrap();
    assert_eq!(code_of(&r), ErrorKind::InvalidInput.code(), "{r}");
    assert!(
        r.get("error")
            .and_then(|v| v.as_str())
            .unwrap()
            .contains("injected fault: frame.decode"),
        "{r}"
    );
    assert!(metrics.frame_errors.load(Ordering::Relaxed) >= 1);
    // the connection is not poisoned — the next frame decodes and serves
    write_frame(
        &mut conn,
        &Json::obj(vec![("id", Json::from(2usize)), ("method", Json::from("ping"))]),
    )
    .unwrap();
    assert_eq!(read_id(&mut conn, 2).get("pong"), Some(&Json::Bool(true)));
    h.stop();
}

/// An injected flush fault fails the whole batch with `internal` but —
/// unlike a worker panic — does **not** quarantine the model: the very
/// next predict serves.
#[test]
fn batcher_flush_fault_fails_batch_without_quarantine() {
    let _g = fault::scoped("batcher.flush=nth:1");
    let h = start(store_with_model(), |_| {});
    let mut conn = connect(&h);
    write_frame(&mut conn, &predict_req(1, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    let r = read_id(&mut conn, 1);
    assert_eq!(code_of(&r), ErrorKind::Internal.code(), "{r}");
    assert!(
        r.get("error")
            .and_then(|v| v.as_str())
            .unwrap()
            .contains("injected fault: batcher.flush"),
        "{r}"
    );
    write_frame(&mut conn, &predict_req(2, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    let r = read_id(&mut conn, 2);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "no quarantine for a flush fault: {r}");
    // injection is visible in the metrics op next to the counters it moved
    let m = metrics_op(&mut conn, 3);
    assert!(m.get("faults_injected").and_then(|v| v.as_f64()).unwrap() >= 1.0, "{m}");
    let internal = m
        .get("err_codes")
        .and_then(|c| c.get("internal"))
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!(internal >= 1.0, "{m}");
    h.stop();
}

/// Serving-boundary validation: non-finite features, wrong feature
/// width, missing models, malformed train parameters and unknown ops
/// are all rejected as `invalid_input` before any compute — and none of
/// them poisons the connection or the model.
#[test]
fn invalid_inputs_are_rejected_at_the_boundary() {
    let _g = fault::scoped("");
    let h = start(store_with_model(), |_| {});
    let mut conn = connect(&h);
    // 1e999 overflows to +inf during JSON number parsing; the predict
    // boundary must refuse to put it in a coalesced GEMM batch
    let raw: &[u8] = b"{\"id\":1,\"method\":\"predict\",\"model\":\"m\",\"x\":[[1e999,0.0,0.0]]}";
    conn.write_all(&encode_frame(raw)).unwrap();
    let r = read_id(&mut conn, 1);
    assert_eq!(code_of(&r), ErrorKind::InvalidInput.code(), "{r}");
    assert!(r.get("error").and_then(|v| v.as_str()).unwrap().contains("not finite"), "{r}");
    // wrong feature width is refused before the batcher
    write_frame(&mut conn, &predict_req(2, "m", &[vec![0.0; 7]])).unwrap();
    assert_eq!(code_of(&read_id(&mut conn, 2)), ErrorKind::InvalidInput.code());
    // unknown model
    write_frame(&mut conn, &predict_req(3, "absent", &[vec![0.0, 0.0, 0.0]])).unwrap();
    assert_eq!(code_of(&read_id(&mut conn, 3)), ErrorKind::InvalidInput.code());
    // malformed train parameters never reach the fitter
    write_frame(
        &mut conn,
        &Json::obj(vec![
            ("id", Json::from(4usize)),
            ("method", Json::from("train")),
            ("name", Json::from("bad")),
            ("dataset", Json::from("bimodal")),
            ("n", Json::from(150usize)),
            ("lambda", Json::Num(-1.0)),
        ]),
    )
    .unwrap();
    assert_eq!(code_of(&read_id(&mut conn, 4)), ErrorKind::InvalidInput.code());
    // unknown op
    write_frame(
        &mut conn,
        &Json::obj(vec![("id", Json::from(5usize)), ("method", Json::from("frobnicate"))]),
    )
    .unwrap();
    assert_eq!(code_of(&read_id(&mut conn, 5)), ErrorKind::InvalidInput.code());
    // none of the above hurt the model or the connection
    write_frame(&mut conn, &predict_req(6, "m", &[vec![0.1, 0.2, 0.3]])).unwrap();
    assert_eq!(read_id(&mut conn, 6).get("ok"), Some(&Json::Bool(true)));
    h.stop();
}

/// Write a small out-of-core training set (X as an f64 file, y as an
/// f64 vector file) and the matching file-backed [`TrainRequest`].
/// Returns the in-memory copies so tests can replicate the fit.
fn out_of_core_fixture(tag: &str) -> (TrainRequest, Matrix, Vec<f64>) {
    let (n, p) = (120usize, 3usize);
    let mut rng = Pcg64::seed(0x00C);
    let x = Matrix::from_fn(n, p, |_, _| rng.normal());
    let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] + x[(i, 1)]).tanh()).collect();
    let xp = std::env::temp_dir().join(format!("accumkrr_chaos_{tag}_x.bin"));
    let yp = std::env::temp_dir().join(format!("accumkrr_chaos_{tag}_y.bin"));
    write_f64_file(&xp.to_string_lossy(), &x).unwrap();
    write_f64_vec(&yp.to_string_lossy(), &y).unwrap();
    let req = TrainRequest {
        name: format!("ooc_{tag}"),
        dataset: String::new(),
        n: 0,
        kind: SketchKind::Accumulation { m: 4 },
        d: 10,
        lambda: 1e-3,
        bandwidth: 0.0,
        seed: 11,
        adaptive: None,
        precision: Precision::F64,
        sampling: SamplingSpec::Uniform,
        data: Some(DataSpec {
            kind: "file".into(),
            path: xp.to_string_lossy().into_owned(),
            dim: p,
            y_path: Some(yp.to_string_lossy().into_owned()),
        }),
    };
    (req, x, y)
}

fn cleanup_out_of_core(req: &TrainRequest) {
    if let Some(spec) = &req.data {
        std::fs::remove_file(&spec.path).ok();
        if let Some(y) = &spec.y_path {
            std::fs::remove_file(y).ok();
        }
    }
}

/// An injected `io.read` failure mid-way through a file-backed fit
/// surfaces as a classified `internal` error — no panic, no model under
/// the name — and a retrain over the same files (fault consumed) heals,
/// landing bitwise on the never-faulted in-memory coefficients: the
/// failed attempt left no poisoned state behind.
#[test]
fn out_of_core_read_fault_is_coded_and_retrain_heals_bitwise() {
    let _g = fault::scoped("io.read=nth:1");
    let (req, x, y) = out_of_core_fixture("readfault");
    let store = ModelStore::new();
    let err = store.train(&req).expect_err("first fill_tile must fail");
    assert_eq!(err.kind, ErrorKind::Internal, "{err:?}");
    assert!(err.msg.contains("io.read"), "{err:?}");
    assert_eq!(fault::fired("io.read"), 1, "nth:1 fires exactly once");
    assert!(store.get(&req.name).is_none(), "failed train must not store a model");
    // the trigger is consumed — the identical request now succeeds
    let meta = store.train(&req).expect("retrain over the same files heals");
    let n = x.rows();
    let mut rng = Pcg64::seed(req.seed);
    let sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 })
        .with_sampling(Sampling::Uniform)
        .build(n, req.d, &mut rng);
    let want = SketchedKrr::fit_with(
        Kernel::matern(1.5, 1.0),
        &x,
        &y,
        &sketch,
        req.lambda,
        None,
        Precision::F64,
    )
    .unwrap();
    assert_eq!(
        meta.model.beta(),
        want.beta(),
        "healed fit must match the never-faulted fit bitwise"
    );
    cleanup_out_of_core(&req);
}

/// Clock eviction under fault pressure never serves a stale tile: with
/// the support-column cache budget forced to zero (every unpinned
/// column evicted as soon as the clock hand reaches it) an adaptive
/// file-backed fit that dies on an injected read mid-round, then
/// retrains, still lands bitwise on the never-faulted zero-budget
/// in-memory fit — re-reads after eviction return the same bytes the
/// first read did.
#[test]
fn cache_eviction_under_read_fault_never_serves_stale_tiles() {
    let _g = fault::scoped("io.read=nth:3");
    std::env::set_var(CACHE_BUDGET_ENV, "0");
    // restore the env var even if an assertion below panics
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            std::env::remove_var(CACHE_BUDGET_ENV);
        }
    }
    let _restore = Restore;
    let (mut req, x, y) = out_of_core_fixture("evict");
    let aopts = AdaptiveOptions {
        m0: 2,
        m_max: 8,
        ..Default::default()
    };
    req.adaptive = Some(aopts.clone());
    let store = ModelStore::new();
    let err = store.train(&req).expect_err("third tile read must fail mid-fit");
    assert_eq!(err.kind, ErrorKind::Internal, "{err:?}");
    assert!(fault::fired("io.read") >= 1);
    let meta = store.train(&req).expect("retrain heals after the fault is consumed");
    let builder = SketchBuilder::new(SketchKind::Accumulation { m: 4 })
        .with_sampling(Sampling::Uniform);
    let (want, _trace) = SketchedKrr::fit_adaptive(
        Kernel::matern(1.5, 1.0),
        &x,
        &y,
        &builder,
        req.d,
        req.lambda,
        &aopts,
        &mut Pcg64::seed(req.seed),
    )
    .unwrap();
    assert_eq!(
        meta.model.beta(),
        want.beta(),
        "eviction + fault + retrain must not change a single bit"
    );
    cleanup_out_of_core(&req);
}

/// Survival under whatever `ACCUMKRR_FAULTS` armed (the CI chaos-matrix
/// legs; a no-op with the registry disarmed): a retrying client pushes
/// mixed traffic through the plane and every outcome is either success
/// or a classified taxonomy error — no deadlock, no unclassified
/// failure, and shutdown stays bounded.
#[test]
fn env_fault_matrix_keeps_the_plane_available() {
    let _g = fault::locked();
    let store = store_with_model();
    let h = start(store, |_| {});
    let mut c = Client::new(ClientConfig {
        addr: h.addr().to_string(),
        retries: 6,
        backoff: Duration::from_millis(2),
        seed: 42,
        legacy: false,
    });
    let mut pongs = 0;
    for i in 0..40usize {
        if i % 2 == 0 {
            // ping is pure transport: with bounded-period io faults and
            // 6 retries it must always get through
            let r = c.call(&Json::obj(vec![("method", Json::from("ping"))])).unwrap();
            assert_eq!(r.get("pong"), Some(&Json::Bool(true)), "{r}");
            pongs += 1;
        } else {
            let req = predict_req(i, "m", &[vec![0.1, 0.2, 0.3]]);
            match c.call(&req) {
                Ok(r) => {
                    if r.get("ok") != Some(&Json::Bool(true)) {
                        let code = code_of(&r);
                        assert!(
                            ErrorKind::from_code(code).is_some(),
                            "unclassified failure: {r}"
                        );
                    }
                }
                Err(e) => panic!("predict transport must retry through: {e}"),
            }
        }
    }
    assert_eq!(pongs, 20);
    // every classified failure the client saw is in the taxonomy
    for code in c.err_code_tally().keys() {
        assert!(ErrorKind::from_code(code).is_some(), "client tallied {code:?}");
    }
    // the metrics op stays serviceable, with the full taxonomy table
    let m = c.call(&Json::obj(vec![("method", Json::from("metrics"))])).unwrap();
    let codes = m.get("err_codes").expect("metrics must carry the err_codes table");
    for k in accumkrr::util::error::ALL {
        assert!(codes.get(k.code()).is_some(), "missing {:?} in {m}", k.code());
    }
    assert!(m.get("faults_injected").and_then(|v| v.as_f64()).is_some(), "{m}");
    let t0 = Instant::now();
    h.stop();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown must stay bounded under the fault matrix"
    );
}
