//! Cross-module integration tests: the full library pipeline (data →
//! sketch → fit → predict → diagnostics) and the coordinator service stack
//! (train → batched predict over TCP).

use accumkrr::coordinator::state::{dataset_for, paper_d, paper_lambda};
use accumkrr::coordinator::{serve, JobScheduler, ModelStore, ServerConfig, TrainRequest};
use accumkrr::data::{bimodal, normalize_features, train_test_split, BimodalConfig};
use accumkrr::kernels::{kernel_matrix, Kernel};
use accumkrr::krr::{falkon, FalkonOptions, KrrModel, SketchedKrr};
use accumkrr::rng::Pcg64;
use accumkrr::sketch::{SketchBuilder, SketchKind};
use accumkrr::stats::{in_sample_sq_error, test_error, SpectralView};
use accumkrr::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// The paper's full pipeline on bimodal data: with paper-style schedules
/// for (λ, d), the accumulation method's approximation error sits within a
/// small factor of Gaussian sketching and far below Nyström, while its
/// runtime stays near Nyström's.
#[test]
fn end_to_end_pipeline_error_ordering() {
    let n = 400;
    let mut rng = Pcg64::seed(42);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let d = ((1.3 * (n as f64).powf(3.0 / 7.0)) as usize).max(2);
    let k = kernel_matrix(&kern, &x);
    let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lambda).unwrap();

    let reps = 10;
    let mean_err = |kind: SketchKind| -> f64 {
        let mut rng = Pcg64::seed(43);
        (0..reps)
            .map(|_| {
                let s = SketchBuilder::new(kind.clone()).build(n, d, &mut rng);
                let m = SketchedKrr::fit(kern, &x, &y, &s, lambda, Some(&k)).unwrap();
                in_sample_sq_error(m.fitted(), exact.fitted())
            })
            .sum::<f64>()
            / reps as f64
    };
    let e_nys = mean_err(SketchKind::Nystrom);
    let e_acc = mean_err(SketchKind::Accumulation { m: 8 });
    let e_gau = mean_err(SketchKind::Gaussian);
    assert!(
        e_acc < e_nys,
        "accumulation {e_acc} should beat nystrom {e_nys}"
    );
    assert!(
        e_acc < 10.0 * e_gau + 1e-9,
        "accumulation {e_acc} should be within a small factor of gaussian {e_gau}"
    );
}

/// K-satisfiability diagnostics agree with observed error: sketches that
/// satisfy both conditions give lower approximation error on average.
#[test]
fn ksat_predicts_approximation_quality() {
    let n = 250;
    let mut rng = Pcg64::seed(7);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(0.6);
    let lambda = 2e-3;
    let k = kernel_matrix(&kern, &x);
    let view = SpectralView::new(&k);
    let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lambda).unwrap();
    let delta = lambda / 2.0;

    let mut sat_errs = Vec::new();
    let mut unsat_errs = Vec::new();
    for trial in 0..24 {
        // mix of weak and strong sketches
        let (kind, d) = if trial % 2 == 0 {
            (SketchKind::Nystrom, 8)
        } else {
            (SketchKind::Accumulation { m: 8 }, 48)
        };
        let s = SketchBuilder::new(kind).build(n, d, &mut rng);
        let rep = accumkrr::stats::k_satisfiability(&view, &s, delta);
        let m = SketchedKrr::fit(kern, &x, &y, &s, lambda, Some(&k)).unwrap();
        let err = in_sample_sq_error(m.fitted(), exact.fitted());
        if rep.cond1 {
            sat_errs.push(err);
        } else {
            unsat_errs.push(err);
        }
    }
    if !sat_errs.is_empty() && !unsat_errs.is_empty() {
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(
            mean(&sat_errs) < mean(&unsat_errs),
            "cond1-satisfying sketches should have lower error: {} vs {}",
            mean(&sat_errs),
            mean(&unsat_errs)
        );
    }
}

/// Falkon and the direct solver agree end-to-end on a real-ish dataset.
#[test]
fn falkon_agrees_with_direct_on_rqa() {
    let mut rng = Pcg64::seed(11);
    let (mut ds, dx, kern) = dataset_for("rqa", 500, 0.0, &mut rng).unwrap();
    normalize_features(&mut ds.x);
    let (train, test) = train_test_split(&ds, 0.2, &mut rng);
    let d = paper_d(train.n(), dx);
    let lambda = paper_lambda(train.n(), dx);
    let s = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(train.n(), d, &mut rng);
    let direct = SketchedKrr::fit(kern, &train.x, &train.y, &s, lambda, None).unwrap();
    let fk = falkon(
        kern,
        &train.x,
        &train.y,
        &s,
        lambda,
        FalkonOptions {
            max_iters: 60,
            tol: 1e-11,
        },
        None,
    )
    .unwrap();
    let e_direct = test_error(&direct.predict(&test.x), &test.y);
    let e_falkon = test_error(&fk.predict(&kern, &test.x), &test.y);
    assert!(
        (e_direct - e_falkon).abs() < 0.05 * (e_direct + e_falkon),
        "direct {e_direct} vs falkon {e_falkon}"
    );
}

/// Full service stack over TCP: train, list, predict (batched), metrics.
#[test]
fn coordinator_tcp_service_end_to_end() {
    let store = Arc::new(ModelStore::new());
    // pre-train one model through the store API
    store
        .train(&TrainRequest {
            name: "pre".into(),
            dataset: "bimodal".into(),
            n: 200,
            kind: SketchKind::Accumulation { m: 4 },
            d: 12,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 9,
            adaptive: None,
            precision: accumkrr::linalg::Precision::F64,
            sampling: accumkrr::coordinator::SamplingSpec::Uniform,
            data: None,
        })
        .unwrap();
    let addr = serve(
        store,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        false,
    )
    .unwrap();

    let conn = TcpStream::connect(addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut request = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut out = String::new();
        reader.read_line(&mut out).unwrap();
        Json::parse(&out).unwrap()
    };

    let r = request(r#"{"op":"train","name":"srv","dataset":"rqa","n":300,"sketch":"accum","m":4,"seed":2}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    let r = request(r#"{"op":"models"}"#);
    assert_eq!(r.get("models").unwrap().as_arr().unwrap().len(), 2);
    let r = request(r#"{"op":"predict","model":"srv","x":[[0.1,0.2,0.5,0.3],[1.0,1.0,0.5,0.5],[0.0,0.0,0.1,0.9]]}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("y").unwrap().as_arr().unwrap().len(), 3);
    let r = request(r#"{"op":"metrics"}"#);
    assert!(r.get("queries").and_then(|q| q.as_usize()).unwrap() >= 3);
    let _ = request(r#"{"op":"shutdown"}"#);
}

/// The job scheduler reproduces identical sweeps across runs (replicate
/// RNG streams are independent of scheduling).
#[test]
fn sweeps_reproducible_across_runs() {
    let run = || {
        JobScheduler::new(5).run_sweep(2, 3, |pt, rng| {
            let cfg = BimodalConfig {
                n: 60,
                gamma: 0.5,
                ..Default::default()
            };
            let (x, y, _) = bimodal(&cfg, rng);
            let s = SketchBuilder::new(SketchKind::Accumulation { m: 2 })
                .build(60, 6 + pt.setting, rng);
            let m = SketchedKrr::fit(Kernel::gaussian(0.5), &x, &y, &s, 1e-2, None).unwrap();
            m.fitted()[0]
        })
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
}
