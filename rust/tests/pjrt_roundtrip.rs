//! PJRT integration: the AOT-compiled JAX/Pallas artifacts, loaded and
//! executed from Rust, must agree with the native Rust implementation of
//! the same math. Requires `make artifacts` (skips with a message if the
//! manifest is absent) and a build with the `xla` feature pointed at the
//! real bindings (the whole file compiles away otherwise).

#![cfg(feature = "xla")]

use accumkrr::data::{bimodal, BimodalConfig};
use accumkrr::kernels::Kernel;
use accumkrr::krr::SketchedKrr;
use accumkrr::linalg::Matrix;
use accumkrr::rng::Pcg64;
use accumkrr::runtime::ModelRuntime;
use accumkrr::sketch::{Sketch, SketchBuilder, SketchKind};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("ACCUMKRR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT tests: {dir}/manifest.json missing (run `make artifacts`)");
        None
    }
}

fn problem(n: usize, d: usize) -> (Matrix, Vec<f64>, Sketch, Kernel, f64) {
    let mut rng = Pcg64::seed(1234);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, &mut rng);
    let kern = Kernel::gaussian(0.6);
    (x, y, sketch, kern, 1e-3)
}

#[test]
fn fit_artifact_matches_native_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::open(&dir).expect("open runtime");
    // n below the bucket (512) to exercise padding
    let (x, y, sketch, kern, lam) = problem(300, 20);
    let Sketch::Sparse(sp) = &sketch else { panic!() };
    let out = rt
        .fit_sketched("gaussian", &x, &y, sp, lam, kern.bandwidth)
        .expect("pjrt fit");
    assert_eq!(out.theta.len(), 20);
    assert_eq!(out.fitted.len(), 300);
    let native = SketchedKrr::fit(kern, &x, &y, &sketch, lam, None).expect("native fit");
    // f32 artifact + CG vs f64 cholesky: compare fitted values loosely
    let mut max_rel = 0.0f64;
    for (a, b) in out.fitted.iter().zip(native.fitted().iter()) {
        let rel = (a - b).abs() / (1.0 + b.abs());
        max_rel = max_rel.max(rel);
    }
    assert!(
        max_rel < 0.05,
        "pjrt vs native fitted values diverge: max rel {max_rel}"
    );
}

#[test]
fn fit_artifact_exact_bucket_size() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::open(&dir).expect("open runtime");
    // exactly the bucket shape: no padding path
    let (x, y, sketch, kern, lam) = problem(512, 32);
    let Sketch::Sparse(sp) = &sketch else { panic!() };
    let out = rt
        .fit_sketched("gaussian", &x, &y, sp, lam, kern.bandwidth)
        .expect("pjrt fit");
    let native = SketchedKrr::fit(kern, &x, &y, &sketch, lam, None).expect("native fit");
    let err: f64 = out
        .fitted
        .iter()
        .zip(native.fitted().iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / 512.0;
    assert!(err < 1e-3, "mse between pjrt and native fitted: {err}");
}

#[test]
fn predict_artifact_matches_native_predict() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::open(&dir).expect("open runtime");
    let (x, y, sketch, kern, lam) = problem(300, 20);
    let native = SketchedKrr::fit(kern, &x, &y, &sketch, lam, None).expect("native fit");
    let Sketch::Sparse(sp) = &sketch else { panic!() };

    // assemble per-column support + weights for the artifact
    let mut support = Vec::new();
    let mut w = Vec::new();
    for j in 0..sp.d() {
        let col = sp.col(j);
        let mut pts = Matrix::zeros(col.len(), x.cols());
        let mut ws = Vec::with_capacity(col.len());
        for (t, &(i, wt)) in col.iter().enumerate() {
            pts.row_mut(t).copy_from_slice(x.row(i));
            ws.push(wt);
        }
        support.push(pts);
        w.push(ws);
    }
    // theta from a PJRT fit
    let fit = rt
        .fit_sketched("gaussian", &x, &y, sp, lam, kern.bandwidth)
        .expect("pjrt fit");

    let mut rng = Pcg64::seed(77);
    let xq = Matrix::from_fn(40, 3, |_, _| rng.uniform());
    let got = rt
        .predict_sketched("gaussian", &xq, &support, &w, &fit.theta, kern.bandwidth)
        .expect("pjrt predict");
    assert_eq!(got.len(), 40);

    // native predict with the same theta: fold through the sketch
    let (sup_idx, beta) = sp.landmark_weights(&fit.theta);
    let landmarks = accumkrr::kernels::gather_rows(&x, &sup_idx);
    let kq = accumkrr::kernels::cross_kernel(&kern, &xq, &landmarks);
    let want = kq.matvec(&beta);
    for (a, b) in got.iter().zip(want.iter()) {
        assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }
    let _ = native;
}

#[test]
fn exact_artifact_matches_native_exact_krr() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::open(&dir).expect("open runtime");
    let n = 200; // pads into the n=256 exact bucket
    let mut rng = Pcg64::seed(55);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(0.7);
    let lam = 5e-3;
    let out = rt
        .fit_exact("gaussian", &x, &y, lam, kern.bandwidth)
        .expect("pjrt exact fit");
    let native = accumkrr::krr::KrrModel::fit(kern, &x, &y, lam).expect("native exact");
    let mse: f64 = out
        .fitted
        .iter()
        .zip(native.fitted().iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        / n as f64;
    assert!(mse < 1e-3, "pjrt vs native exact KRR fitted mse {mse}");
}

#[test]
fn manifest_lists_all_entry_points() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::open(&dir).expect("open runtime");
    let entries: std::collections::BTreeSet<&str> = rt
        .manifest()
        .artifacts
        .iter()
        .map(|a| a.entry.as_str())
        .collect();
    assert!(entries.contains("fit_sketched"));
    assert!(entries.contains("predict_sketched"));
    assert!(entries.contains("fit_exact"));
    assert!(rt.platform().to_lowercase().contains("cpu") || rt.platform().to_lowercase().contains("host"));
}
