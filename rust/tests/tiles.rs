//! Cross-backend bitwise equivalence harness for the out-of-core
//! `TileSource` backends (DESIGN.md §12).
//!
//! The determinism contract says training results are a function of the
//! *bytes* of `X`, not of where they live or how they are scheduled:
//! every backend feeds exact f64 tiles through the same fixed-width
//! column-blocked assembly, and each output row has a single owner. So
//! sketched-KRR coefficients, adaptive fits and spectral-cluster labels
//! must be **bitwise identical** across
//!
//! * backend ∈ {in-memory [`Matrix`], [`F64File`], [`ShardedFile`]},
//! * row-tile height ∈ {1, odd, default, n} (via `ACCUMKRR_ROW_TILE`),
//! * worker threads ∈ {1, 4}.
//!
//! Every leg runs under `assembly_guard`, pinning the "streamed paths
//! never assemble the `n×n` kernel" contract at the same time.
//!
//! This suite owns its process (its own integration-test binary), but
//! the `#[test]` fns inside it share the process-global row-tile env
//! var and pool width — they serialize on a local mutex.

use accumkrr::cluster::{SpectralClustering, SpectralOptions};
use accumkrr::data::{write_f64_file, write_shards, F64File, ShardedFile, TileSource};
use accumkrr::kernels::{assembly_guard, Kernel, DEFAULT_TILE, ROW_TILE_ENV};
use accumkrr::krr::{AdaptiveOptions, SketchedKrr};
use accumkrr::linalg::{Matrix, Precision};
use accumkrr::pool;
use accumkrr::rng::Pcg64;
use accumkrr::sketch::{SketchBuilder, SketchKind};
use std::path::PathBuf;
use std::sync::Mutex;

/// Serializes the tests in this binary: they mutate the process-global
/// row-tile override and thread-pool width. (`pool`'s own test lock is
/// crate-private; integration tests are a separate crate.)
static GLOBAL_STATE: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_STATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the row-tile env var and pool width even if a leg panics.
struct StateGuard {
    prev_threads: usize,
}

impl StateGuard {
    fn new() -> StateGuard {
        StateGuard {
            prev_threads: pool::num_threads(),
        }
    }
}

impl Drop for StateGuard {
    fn drop(&mut self) {
        std::env::remove_var(ROW_TILE_ENV);
        pool::set_num_threads(self.prev_threads);
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

/// Deterministic feature matrix: standard normals from a pinned stream.
fn random_x(n: usize, p: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seed(seed);
    Matrix::from_fn(n, p, |_, _| rng.normal())
}

/// Two well-separated Gaussian blobs (rows 0..n/2 near -2, rest near +2)
/// so the cluster test has an unambiguous 2-way structure.
fn blob_x(n: usize, p: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::seed(seed);
    Matrix::from_fn(n, p, |i, _| {
        let c = if i < n / 2 { -2.0 } else { 2.0 };
        c + 0.3 * rng.normal()
    })
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// Write `x` to a fresh f64 file and shard directory (shard height is
/// deliberately not a divisor of `n`, so tiles straddle boundaries and
/// the final shard is ragged), then run `leg` once per backend. The
/// in-memory matrix itself is the third backend (unsized coercion to
/// `&dyn TileSource`).
fn for_each_backend(tag: &str, x: &Matrix, leg: &mut dyn FnMut(&str, &dyn TileSource)) {
    let file = tmp(&format!("accumkrr_tiles_it_{tag}.bin"));
    let dir = tmp(&format!("accumkrr_tiles_it_{tag}_shards"));
    write_f64_file(&file.to_string_lossy(), x).expect("write f64 file");
    let shard_rows = (x.rows() / 3).max(1) + 1;
    write_shards(&dir.to_string_lossy(), x, shard_rows).expect("write shards");

    leg("memory", x);
    let f = F64File::open(&file.to_string_lossy(), x.cols()).expect("open f64 file");
    leg("file", &f);
    let s = ShardedFile::open(&dir.to_string_lossy()).expect("open shards");
    leg("shards", &s);

    std::fs::remove_file(&file).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// The (tile, threads) grid every fit below is pinned across. Tile 1 is
/// the degenerate schedule, 37 an odd non-divisor, `DEFAULT_TILE` the
/// production height, `n` a single whole-matrix tile.
fn tile_grid(n: usize) -> [usize; 4] {
    [1, 37, DEFAULT_TILE, n]
}

const THREADS: [usize; 2] = [1, 4];

/// Sketched-KRR coefficients are bitwise identical across all three
/// backends × 4 tile heights × 2 thread widths, and no leg assembles an
/// `n×n` kernel.
#[test]
fn fit_is_bitwise_identical_across_backends_tiles_and_threads() {
    let _g = lock();
    let _restore = StateGuard::new();
    let (n, p, d, lambda) = (96usize, 4usize, 12usize, 1e-3);
    let kern = Kernel::matern(1.5, 1.0);
    let x = random_x(n, p, 0xA110);
    let y: Vec<f64> = (0..n).map(|i| (x[(i, 0)] - x[(i, 1)]).sin()).collect();
    let mut rng = Pcg64::seed(0xBEEF);
    let sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, &mut rng);

    // reference: in-memory, default tile, one worker
    std::env::remove_var(ROW_TILE_ENV);
    pool::set_num_threads(1);
    let reference = SketchedKrr::fit_with(kern, &x, &y, &sketch, lambda, None, Precision::F64)
        .expect("reference fit");
    let want = bits(reference.beta());

    for tile in tile_grid(n) {
        std::env::set_var(ROW_TILE_ENV, tile.to_string());
        for threads in THREADS {
            pool::set_num_threads(threads);
            for_each_backend("fit", &x, &mut |name, src| {
                assembly_guard::reset();
                let model =
                    SketchedKrr::fit_with(kern, src, &y, &sketch, lambda, None, Precision::F64)
                        .expect("streamed fit");
                assert!(
                    assembly_guard::max_square() < n,
                    "{name} tile={tile} threads={threads}: assembled an n×n kernel"
                );
                assert_eq!(
                    bits(model.beta()),
                    want,
                    "beta drifted: backend={name} tile={tile} threads={threads}"
                );
            });
        }
    }
}

/// The adaptive engine (incremental accumulation + stopping rule) lands
/// on the same rounds and bitwise-equal coefficients regardless of
/// backend, tile height or thread width: every quantity the stopping
/// rule inspects is itself bitwise pinned.
#[test]
fn fit_adaptive_is_bitwise_identical_across_backends_tiles_and_threads() {
    let _g = lock();
    let _restore = StateGuard::new();
    let (n, p, d, lambda) = (80usize, 3usize, 10usize, 1e-3);
    let kern = Kernel::matern(1.5, 1.0);
    let x = random_x(n, p, 0xADA);
    let y: Vec<f64> = (0..n).map(|i| x[(i, 0)].tanh() + 0.1 * x[(i, 2)]).collect();
    let builder = SketchBuilder::new(SketchKind::Accumulation { m: 2 });
    let aopts = AdaptiveOptions {
        m0: 2,
        m_max: 8,
        ..AdaptiveOptions::default()
    };

    std::env::remove_var(ROW_TILE_ENV);
    pool::set_num_threads(1);
    let (ref_model, ref_trace) = SketchedKrr::fit_adaptive(
        kern,
        &x,
        &y,
        &builder,
        d,
        lambda,
        &aopts,
        &mut Pcg64::seed(7),
    )
    .expect("reference adaptive fit");
    let want = bits(ref_model.beta());

    for tile in tile_grid(n) {
        std::env::set_var(ROW_TILE_ENV, tile.to_string());
        for threads in THREADS {
            pool::set_num_threads(threads);
            for_each_backend("adaptive", &x, &mut |name, src| {
                assembly_guard::reset();
                // fresh, identically seeded stream per leg: identical
                // intermediate values => identical draw sequence
                let (model, trace) = SketchedKrr::fit_adaptive(
                    kern,
                    src,
                    &y,
                    &builder,
                    d,
                    lambda,
                    &aopts,
                    &mut Pcg64::seed(7),
                )
                .expect("streamed adaptive fit");
                assert!(
                    assembly_guard::max_square() < n,
                    "{name} tile={tile} threads={threads}: assembled an n×n kernel"
                );
                assert_eq!(
                    trace.len(),
                    ref_trace.len(),
                    "round count drifted: backend={name} tile={tile} threads={threads}"
                );
                assert_eq!(
                    bits(model.beta()),
                    want,
                    "adaptive beta drifted: backend={name} tile={tile} threads={threads}"
                );
            });
        }
    }
}

/// Streamed spectral clustering pins labels *and* the raw embedding
/// bitwise across the full backend × tile × thread grid.
#[test]
fn spectral_cluster_is_bitwise_identical_across_backends_tiles_and_threads() {
    let _g = lock();
    let _restore = StateGuard::new();
    let (n, p) = (90usize, 3usize);
    let kern = Kernel::gaussian(1.5);
    let x = blob_x(n, p, 0xC105);
    let opts = SpectralOptions {
        k: 2,
        ..SpectralOptions::default()
    };

    std::env::remove_var(ROW_TILE_ENV);
    pool::set_num_threads(1);
    let reference = SpectralClustering::fit(kern, &x, &opts, &mut Pcg64::seed(9))
        .expect("reference clustering");
    let want_embed = bits(reference.embedding.data());

    for tile in tile_grid(n) {
        std::env::set_var(ROW_TILE_ENV, tile.to_string());
        for threads in THREADS {
            pool::set_num_threads(threads);
            for_each_backend("cluster", &x, &mut |name, src| {
                assembly_guard::reset();
                let got = SpectralClustering::fit(kern, src, &opts, &mut Pcg64::seed(9))
                    .expect("streamed clustering");
                assert!(
                    assembly_guard::max_square() < n,
                    "{name} tile={tile} threads={threads}: assembled an n×n kernel"
                );
                assert_eq!(
                    got.labels, reference.labels,
                    "labels drifted: backend={name} tile={tile} threads={threads}"
                );
                assert_eq!(
                    bits(got.embedding.data()),
                    want_embed,
                    "embedding drifted: backend={name} tile={tile} threads={threads}"
                );
            });
        }
    }
}

/// Seeded shard-boundary property test: 64 random (n, p, shard height,
/// tile span) configurations where the shard height never divides `n`
/// (ragged final shard) and the probed tile straddles at least two
/// shards. `fill_tile` must return the exact bytes of the in-memory
/// rows for every probe, including the whole-matrix span.
#[test]
fn shard_boundary_tiles_match_in_memory_bytes() {
    let mut rng = Pcg64::seed(0x5EED_2021);
    for trial in 0..64u64 {
        let n = 11 + rng.below(110) as usize;
        let p = 1 + rng.below(6) as usize;
        // shard height: >= 2 shards, non-divisor so the last is ragged
        let mut shard_rows = 0usize;
        for _ in 0..256 {
            let s = 1 + rng.below((n / 2) as u64) as usize;
            if n % s != 0 {
                shard_rows = s;
                break;
            }
        }
        assert!(shard_rows >= 1, "trial {trial}: no ragged shard height for n={n}");

        let x = random_x(n, p, 0x7EA + trial);
        let dir = tmp(&format!("accumkrr_tiles_it_prop_{trial}"));
        write_shards(&dir.to_string_lossy(), &x, shard_rows).expect("write shards");
        let src = ShardedFile::open(&dir.to_string_lossy()).expect("open shards");
        assert_eq!(src.rows(), n);
        assert_eq!(src.dim(), p);

        let check = |r0: usize, r1: usize| {
            let mut out = vec![0.0f64; (r1 - r0) * p];
            src.fill_tile(r0, r1, &mut out).expect("fill_tile");
            assert_eq!(
                bits(&out),
                bits(&x.data()[r0 * p..r1 * p]),
                "trial {trial}: n={n} p={p} shard_rows={shard_rows} span={r0}..{r1}"
            );
        };

        // a span guaranteed to straddle >= 1 boundary (starts inside
        // shard 0, ends past it)
        let r0 = rng.below(shard_rows as u64) as usize;
        let r1 = shard_rows + 1 + rng.below((n - shard_rows) as u64) as usize;
        check(r0, r1.min(n));
        // a span ending inside the ragged final shard
        let last_start = n - n % shard_rows;
        check(last_start.saturating_sub(1 + rng.below(shard_rows as u64) as usize), n);
        // the whole matrix in one tile
        check(0, n);
        // an empty tile at a random offset
        let at = rng.below((n + 1) as u64) as usize;
        check(at, at);

        std::fs::remove_dir_all(&dir).ok();
    }
}
