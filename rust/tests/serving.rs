//! End-to-end tests for the async serving plane: framed protocol,
//! legacy interop, robustness against malformed input, backpressure,
//! bounded shutdown, and bitwise-stable predictions across batch
//! compositions.

use accumkrr::coordinator::frame::{read_frame, write_frame, MAX_FRAME};
use accumkrr::coordinator::state::{SamplingSpec, TrainRequest};
use accumkrr::coordinator::{BatcherConfig, ModelStore, ServerConfig, ServerHandle};
use accumkrr::linalg::Precision;
use accumkrr::sketch::SketchKind;
use accumkrr::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A store holding one small pre-trained model named `m` (bimodal → 3
/// feature columns).
fn store_with_model() -> Arc<ModelStore> {
    let store = Arc::new(ModelStore::new());
    store
        .train(&TrainRequest {
            name: "m".into(),
            dataset: "bimodal".into(),
            n: 150,
            kind: SketchKind::Accumulation { m: 3 },
            d: 10,
            lambda: 1e-3,
            bandwidth: 0.0,
            seed: 5,
            adaptive: None,
            precision: Precision::F64,
            sampling: SamplingSpec::Uniform,
            data: None,
        })
        .unwrap();
    store
}

fn start(store: Arc<ModelStore>, tweak: impl FnOnce(&mut ServerConfig)) -> ServerHandle {
    let mut cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    };
    tweak(&mut cfg);
    ServerHandle::start(store, cfg).unwrap()
}

fn connect(h: &ServerHandle) -> TcpStream {
    let c = TcpStream::connect(h.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    c
}

/// Read framed replies until one matches the wanted id.
fn read_id(conn: &mut TcpStream, want: usize) -> Json {
    loop {
        let j = read_frame(conn).unwrap();
        if j.get("id").and_then(|v| v.as_usize()) == Some(want) {
            return j;
        }
    }
}

fn predict_req(id: usize, rows: &[Vec<f64>]) -> Json {
    Json::obj(vec![
        ("id", Json::from(id)),
        ("method", Json::from("predict")),
        ("model", Json::from("m")),
        (
            "x",
            Json::Arr(rows.iter().map(|r| Json::nums(r)).collect()),
        ),
    ])
}

#[test]
fn framed_protocol_end_to_end_with_envelope() {
    let h = start(store_with_model(), |_| {});
    let mut conn = connect(&h);
    // ping: envelope injects ok + echoes method and id
    write_frame(
        &mut conn,
        &Json::obj(vec![("id", Json::from(1usize)), ("method", Json::from("ping"))]),
    )
    .unwrap();
    let r = read_id(&mut conn, 1);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("method").and_then(|v| v.as_str()), Some("ping"));
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));
    // predict through the batcher
    write_frame(&mut conn, &predict_req(7, &[vec![0.1, 0.2, 0.3], vec![1.0, -1.0, 0.5]]))
        .unwrap();
    let r = read_id(&mut conn, 7);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.get("method").and_then(|v| v.as_str()), Some("predict"));
    assert_eq!(r.get("y").and_then(|v| v.as_arr()).unwrap().len(), 2);
    // errors carry BOTH err and error keys in the framed envelope
    write_frame(
        &mut conn,
        &Json::obj(vec![
            ("id", Json::from(9usize)),
            ("method", Json::from("predict")),
            ("model", Json::from("nope")),
            ("x", Json::Arr(vec![Json::nums(&[0.0, 0.0, 0.0])])),
        ]),
    )
    .unwrap();
    let r = read_id(&mut conn, 9);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert!(r.get("err").is_some() && r.get("error").is_some(), "{r}");
    // metrics reflects the served rows
    write_frame(
        &mut conn,
        &Json::obj(vec![("id", Json::from(2usize)), ("method", Json::from("metrics"))]),
    )
    .unwrap();
    let r = read_id(&mut conn, 2);
    assert!(r.get("queries").and_then(|v| v.as_usize()).unwrap() >= 2, "{r}");
    assert!(r.get("predict_latency_ms").is_some(), "{r}");
    h.stop();
}

#[test]
fn legacy_and_framed_pipelined_mixed_clients() {
    let h = start(store_with_model(), |_| {});
    // legacy client: three requests in ONE write; replies must come back
    // newline-delimited, in order
    let mut legacy = connect(&h);
    legacy
        .write_all(
            b"{\"op\":\"ping\"}\n{\"op\":\"predict\",\"model\":\"m\",\"x\":[[0.5,0.5,0.5]]}\n{\"op\":\"models\"}\n",
        )
        .unwrap();
    let mut reader = BufReader::new(legacy.try_clone().unwrap());
    let mut lines = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        lines.push(Json::parse(&line).unwrap());
    }
    assert_eq!(lines[0].get("pong"), Some(&Json::Bool(true)), "{}", lines[0]);
    assert_eq!(lines[1].get("y").and_then(|v| v.as_arr()).unwrap().len(), 1);
    assert!(lines[2].get("models").is_some(), "{}", lines[2]);
    // framed client on the same server, pipelined in one write; replies
    // are matched by id, order not guaranteed
    let mut framed = connect(&h);
    let mut burst = Vec::new();
    for id in [11usize, 12, 13] {
        burst.extend_from_slice(&accumkrr::coordinator::frame::frame_msg(&Json::obj(vec![
            ("id", Json::from(id)),
            ("method", Json::from("ping")),
        ])));
    }
    framed.write_all(&burst).unwrap();
    let mut seen = Vec::new();
    for _ in 0..3 {
        let j = read_frame(&mut framed).unwrap();
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j}");
        seen.push(j.get("id").and_then(|v| v.as_usize()).unwrap());
    }
    seen.sort();
    assert_eq!(seen, vec![11, 12, 13]);
    h.stop();
}

#[test]
fn malformed_input_gets_structured_error_without_poisoning() {
    let h = start(store_with_model(), |_| {});
    // framed: garbage payload → bad json error, connection still works
    let mut conn = connect(&h);
    let garbage = b"this is not json";
    let mut msg = (garbage.len() as u32).to_be_bytes().to_vec();
    msg.extend_from_slice(garbage);
    conn.write_all(&msg).unwrap();
    let r = read_frame(&mut conn).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert!(
        r.get("error").and_then(|v| v.as_str()).unwrap().contains("bad json"),
        "{r}"
    );
    write_frame(&mut conn, &Json::obj(vec![("method", Json::from("ping"))])).unwrap();
    let r = read_frame(&mut conn).unwrap();
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)), "same conn survives: {r}");
    // legacy: a garbage line errors, the next line still answers
    let mut legacy = connect(&h);
    legacy.write_all(b"wat wat\n{\"op\":\"ping\"}\n").unwrap();
    let mut reader = BufReader::new(legacy);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("ok"), Some(&Json::Bool(false)));
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("pong"), Some(&Json::Bool(true)));
    h.stop();
}

#[test]
fn oversized_half_written_and_unknown_protocol_frames() {
    let h = start(store_with_model(), |_| {});
    // oversized header: structured error then the connection closes
    let mut conn = connect(&h);
    conn.write_all(&((MAX_FRAME + 1) as u32).to_be_bytes()).unwrap();
    let r = read_frame(&mut conn).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
    assert!(
        r.get("error").and_then(|v| v.as_str()).unwrap().contains("exceeds"),
        "{r}"
    );
    assert!(read_frame(&mut conn).is_err(), "server must close after oversize");
    // half-written frame then client write-close: server must drop the
    // connection instead of waiting forever
    let mut conn = connect(&h);
    let mut partial = (100u32).to_be_bytes().to_vec();
    partial.extend_from_slice(&[0u8; 10]);
    conn.write_all(&partial).unwrap();
    conn.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    assert_eq!(conn.read_to_end(&mut buf).unwrap(), 0, "no reply for half frame");
    // unknown first byte: error reply, then close
    let mut conn = connect(&h);
    conn.write_all(&[0xFFu8]).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let r = Json::parse(&line).unwrap();
    assert!(
        r.get("error").and_then(|v| v.as_str()).unwrap().contains("protocol"),
        "{r}"
    );
    // server stays healthy for well-formed clients afterwards
    let mut ok_conn = connect(&h);
    write_frame(&mut ok_conn, &Json::obj(vec![("method", Json::from("ping"))])).unwrap();
    assert_eq!(
        read_frame(&mut ok_conn).unwrap().get("pong"),
        Some(&Json::Bool(true))
    );
    h.stop();
}

#[test]
fn overload_sheds_pipelined_burst_deterministically() {
    let h = start(store_with_model(), |cfg| {
        cfg.max_inflight = 1;
    });
    let metrics = h.metrics();
    let mut conn = connect(&h);
    // three pings in one write: the reactor parses the burst before any
    // completion is applied, so #2 and #3 exceed max_inflight=1 and shed
    let mut burst = Vec::new();
    for id in [1usize, 2, 3] {
        burst.extend_from_slice(&accumkrr::coordinator::frame::frame_msg(&Json::obj(vec![
            ("id", Json::from(id)),
            ("method", Json::from("ping")),
        ])));
    }
    conn.write_all(&burst).unwrap();
    let mut ok = 0;
    let mut overloaded = 0;
    for _ in 0..3 {
        let r = read_frame(&mut conn).unwrap();
        if r.get("ok") == Some(&Json::Bool(true)) {
            ok += 1;
        } else {
            assert_eq!(r.get("err").and_then(|v| v.as_str()), Some("overloaded"), "{r}");
            overloaded += 1;
        }
    }
    assert_eq!((ok, overloaded), (1, 2));
    assert_eq!(metrics.shed.load(std::sync::atomic::Ordering::Relaxed), 2);
    // shed is per-request, not a connection death sentence
    write_frame(
        &mut conn,
        &Json::obj(vec![("id", Json::from(4usize)), ("method", Json::from("ping"))]),
    )
    .unwrap();
    assert_eq!(read_id(&mut conn, 4).get("ok"), Some(&Json::Bool(true)));
    h.stop();
}

#[test]
fn shutdown_completes_in_bounded_time() {
    let h = start(store_with_model(), |_| {});
    let mut conn = connect(&h);
    let t0 = Instant::now();
    write_frame(&mut conn, &Json::obj(vec![("method", Json::from("shutdown"))])).unwrap();
    let r = read_frame(&mut conn).unwrap();
    assert_eq!(r.get("stopping"), Some(&Json::Bool(true)), "{r}");
    h.join();
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(2),
        "shutdown took {elapsed:?}, want < 2s"
    );
}

/// The serving acceptance bar: a row's prediction is bitwise identical
/// no matter the concurrency level or which batch composition it rides
/// in. Solo baseline first, then concurrent clients hammering mixed
/// batches while re-asking for the probe row.
#[test]
fn predictions_bitwise_stable_across_concurrency_and_batches() {
    let h = start(store_with_model(), |cfg| {
        // long fixed wait forces heavy coalescing across clients
        cfg.batcher = BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(5),
            adaptive: true,
        };
    });
    let probe = vec![0.37, -1.2, 0.88];
    let solo = {
        let mut conn = connect(&h);
        write_frame(&mut conn, &predict_req(1, std::slice::from_ref(&probe))).unwrap();
        let r = read_id(&mut conn, 1);
        r.get("y").and_then(|v| v.as_arr()).unwrap()[0].as_f64().unwrap()
    };
    let addr = h.addr();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let probe = probe.clone();
        handles.push(std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.set_nodelay(true).unwrap();
            let mut got = Vec::new();
            for i in 0..6usize {
                // vary the batch composition: filler rows around the
                // probe, at shifting positions
                let mut rows = Vec::new();
                for f in 0..(t as usize % 3) {
                    rows.push(vec![t as f64 + f as f64, -1.0, 0.5]);
                }
                rows.push(probe.clone());
                for f in 0..i {
                    rows.push(vec![0.1 * f as f64, 2.0, -0.7]);
                }
                let probe_pos = t as usize % 3;
                let req = Json::obj(vec![
                    ("id", Json::from(i)),
                    ("method", Json::from("predict")),
                    ("model", Json::from("m")),
                    ("x", Json::Arr(rows.iter().map(|r| Json::nums(r)).collect())),
                ]);
                write_frame(&mut conn, &req).unwrap();
                let r = loop {
                    let j = read_frame(&mut conn).unwrap();
                    if j.get("id").and_then(|v| v.as_usize()) == Some(i) {
                        break j;
                    }
                };
                let y = r.get("y").and_then(|v| v.as_arr()).unwrap();
                got.push(y[probe_pos].as_f64().unwrap());
            }
            got
        }));
    }
    for hnd in handles {
        for y in hnd.join().unwrap() {
            assert_eq!(
                y.to_bits(),
                solo.to_bits(),
                "probe row drifted under concurrency: {y} vs solo {solo}"
            );
        }
    }
    h.stop();
}

/// Metrics counters only ever grow, and the latency histogram stays
/// internally consistent as traffic accumulates.
#[test]
fn metrics_are_monotone_under_traffic() {
    let h = start(store_with_model(), |_| {});
    let mut conn = connect(&h);
    let fetch = |conn: &mut TcpStream, id: usize| -> Json {
        write_frame(
            conn,
            &Json::obj(vec![("id", Json::from(id)), ("method", Json::from("metrics"))]),
        )
        .unwrap();
        read_id(conn, id)
    };
    let mut last_q = 0;
    for round in 0..3usize {
        for i in 0..4usize {
            write_frame(&mut conn, &predict_req(100 + i, &[vec![0.1 * i as f64, 0.5, -0.5]]))
                .unwrap();
            read_id(&mut conn, 100 + i);
        }
        let m = fetch(&mut conn, 900 + round);
        let q = m.get("queries").and_then(|v| v.as_usize()).unwrap();
        assert!(q >= last_q + 4, "queries must grow: {q} after {last_q}");
        last_q = q;
        let lat = m.get("predict_latency_ms").unwrap();
        let p50 = lat.get("p50").and_then(|v| v.as_f64()).unwrap();
        let p99 = lat.get("p99").and_then(|v| v.as_f64()).unwrap();
        assert!(p50 >= 0.0 && p99 >= p50, "p50={p50} p99={p99}");
        let br = m.get("batch_rows").unwrap();
        assert!(br.get("count").and_then(|v| v.as_f64()).unwrap() >= 1.0);
    }
    h.stop();
}
