//! Property-based tests over the coordinator/sketch/linalg invariants,
//! using the in-crate `util::check` mini-framework (no proptest offline).

use accumkrr::kernels::{kernel_matrix, Kernel};
use accumkrr::linalg::{chol_factor, eigh, matmul, matmul_at_b, syrk_at_a, Matrix};
use accumkrr::sketch::{Sampling, Sketch, SketchBuilder, SketchKind, SketchOps};
use accumkrr::util::check::{check, Gen};

fn random_kind(g: &mut Gen) -> SketchKind {
    match g.int(0, 4) {
        0 => SketchKind::Nystrom,
        1 => SketchKind::Accumulation { m: g.int(1, 12) },
        2 => SketchKind::Gaussian,
        3 => SketchKind::Rademacher,
        _ => SketchKind::VerySparse {
            sparsity: Some(g.f64(1.0, 8.0)),
        },
    }
}

/// Every sketch construction: shape, finiteness, and the st_mat/s_vec
/// adjoint identity ⟨Sᵀb, w⟩ = ⟨b, Sw⟩.
#[test]
fn prop_sketch_adjoint_identity() {
    check("sketch adjoint", 40, |g| {
        let n = g.int(2, 60);
        let d = g.int(1, 20);
        let kind = random_kind(g);
        let s = SketchBuilder::new(kind).build(n, d, g.rng());
        assert_eq!((s.n(), s.d()), (n, d));
        let b: Vec<f64> = g.normals(n);
        let w: Vec<f64> = g.normals(d);
        let stb = s.st_vec(&b);
        let sw = s.s_vec(&w);
        let lhs: f64 = stb.iter().zip(w.iter()).map(|(a, b)| a * b).sum();
        let rhs: f64 = b.iter().zip(sw.iter()).map(|(a, b)| a * b).sum();
        assert!(
            (lhs - rhs).abs() < 1e-8 * (1.0 + lhs.abs().max(rhs.abs())),
            "adjoint violated: {lhs} vs {rhs}"
        );
    });
}

/// Sparse fast path ≡ dense math for every sparse construction and any
/// weighted sampling distribution.
#[test]
fn prop_sparse_gram_matches_dense() {
    check("sparse gram vs dense", 25, |g| {
        let n = g.int(4, 40);
        let d = g.int(1, 10);
        let p = g.int(1, 4);
        let x = Matrix::from_fn(n, p, |_, _| g.normal());
        let kern = *g.choose(&[
            Kernel::gaussian(0.8),
            Kernel::matern(1.5, 1.0),
            Kernel::matern(0.5, 1.2),
        ]);
        let sampling = if g.bool(0.5) {
            Sampling::Uniform
        } else {
            Sampling::Weighted(accumkrr::rng::AliasTable::new(&g.weights(n)))
        };
        let m = g.int(1, 6);
        let s = SketchBuilder::new(SketchKind::Accumulation { m })
            .with_sampling(sampling)
            .build(n, d, g.rng());
        let gram = accumkrr::sketch::sketch_gram(&kern, &x, &s, None);
        let k = kernel_matrix(&kern, &x);
        let sd = s.to_dense();
        let ks_ref = matmul(&k, &sd);
        for i in 0..n {
            for j in 0..d {
                assert!(
                    (gram.ks[(i, j)] - ks_ref[(i, j)]).abs() < 1e-8,
                    "KS mismatch at ({i},{j})"
                );
            }
        }
        let stks_ref = matmul_at_b(&sd, &ks_ref);
        for i in 0..d {
            for j in 0..d {
                assert!((gram.stks[(i, j)] - stks_ref[(i, j)]).abs() < 1e-8);
            }
        }
    });
}

/// SᵀKS is PSD for any sketch (K is PSD): its eigenvalues are ≥ −ε.
#[test]
fn prop_sketched_gram_psd() {
    check("SᵀKS psd", 20, |g| {
        let n = g.int(4, 30);
        let d = g.int(1, 8);
        let p = g.int(1, 3);
        let x = Matrix::from_fn(n, p, |_, _| g.f64(0.0, 2.0));
        let kern = Kernel::gaussian(g.f64(0.3, 1.5));
        let kind = random_kind(g);
        let s = SketchBuilder::new(kind).build(n, d, g.rng());
        let gram = accumkrr::sketch::sketch_gram(&kern, &x, &s, None);
        let eig = eigh(&gram.stks);
        let max = eig.w.last().copied().unwrap_or(0.0).max(1.0);
        assert!(
            eig.w.iter().all(|&w| w > -1e-8 * max),
            "negative eigenvalue in SᵀKS: {:?}",
            eig.w
        );
    });
}

/// Cholesky solve is an inverse: A·solve(A, b) = b for random SPD A.
#[test]
fn prop_chol_solve_inverse() {
    check("chol solve", 30, |g| {
        let n = g.int(1, 25);
        let b = Matrix::from_fn(n + 2, n, |_, _| g.normal());
        let mut a = syrk_at_a(&b);
        a.add_diag(g.f64(0.1, 2.0));
        let rhs: Vec<f64> = g.normals(n);
        let x = chol_factor(&a).expect("spd").solve(&rhs);
        let back = a.matvec(&x);
        for (u, v) in back.iter().zip(rhs.iter()) {
            assert!((u - v).abs() < 1e-6, "{u} vs {v}");
        }
    });
}

/// eigh reconstructs: ‖A − VΛVᵀ‖∞ small, V orthonormal.
#[test]
fn prop_eigh_reconstructs() {
    check("eigh reconstruct", 20, |g| {
        let n = g.int(1, 20);
        let mut a = Matrix::from_fn(n, n, |_, _| g.normal());
        let at = a.transpose();
        a.axpy(1.0, &at);
        a.scale(0.5);
        let res = eigh(&a);
        // A v = λ v
        for j in 0..n {
            let v = res.v.col(j);
            let av = a.matvec(&v);
            for i in 0..n {
                assert!(
                    (av[i] - res.w[j] * v[i]).abs() < 1e-7 * (1.0 + res.w[j].abs()),
                    "eigpair {j}"
                );
            }
        }
    });
}

/// The kernel matrix is PSD for all radial kernels over random data:
/// quadratic forms are non-negative.
#[test]
fn prop_kernel_matrix_psd() {
    check("kernel psd", 25, |g| {
        let n = g.int(2, 30);
        let p = g.int(1, 4);
        let x = Matrix::from_fn(n, p, |_, _| g.normal());
        let kern = *g.choose(&[
            Kernel::gaussian(0.7),
            Kernel::matern(0.5, 1.0),
            Kernel::matern(1.5, 0.9),
            Kernel::matern(2.5, 1.1),
            Kernel::laplacian(1.0),
        ]);
        let k = kernel_matrix(&kern, &x);
        let v: Vec<f64> = g.normals(n);
        let q: f64 = k.matvec(&v).iter().zip(v.iter()).map(|(a, b)| a * b).sum();
        assert!(q > -1e-8 * n as f64, "quadratic form negative: {q}");
    });
}

/// Landmark folding is exact: predict-via-landmarks == KSθ on training
/// points for sparse sketches.
#[test]
fn prop_landmark_fold_exact() {
    check("landmark fold", 15, |g| {
        let n = g.int(6, 40);
        let d = g.int(1, 8);
        let m = g.int(1, 5);
        let p = g.int(1, 3);
        let x = Matrix::from_fn(n, p, |_, _| g.f64(0.0, 1.0));
        let y: Vec<f64> = g.normals(n);
        let kern = Kernel::gaussian(0.6);
        let s = SketchBuilder::new(SketchKind::Accumulation { m }).build(n, d, g.rng());
        if let Some(model) =
            accumkrr::krr::SketchedKrr::fit(kern, &x, &y, &s, 1e-2, None)
        {
            let pred = model.predict(&x);
            for (a, b) in pred.iter().zip(model.fitted().iter()) {
                assert!((a - b).abs() < 1e-6, "{a} vs {b}");
            }
            // landmark count bounded by sketch support
            if let Sketch::Sparse(sp) = &s {
                assert!(model.num_landmarks() <= sp.support().len());
            }
        }
    });
}
