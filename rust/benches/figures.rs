//! `cargo bench --bench figures` — quick-scale regeneration of every paper
//! figure (full scale via the CLI: `accumkrr bench <id> --full`).
use accumkrr::bench::{self, BenchOpts};

fn main() {
    let quick = BenchOpts {
        replicates: 3,
        n_max: 1000,
        ..Default::default()
    };
    for id in [
        "fig1", "fig2", "fig3", "fig4", "fig5", "thm8", "cost", "cluster",
        "ext-sketches", "ext-amm", "ext-kpca",
    ] {
        let rows = bench::run(id, &quick).expect("bench");
        bench::print_table(id, &rows, &None);
    }
}
