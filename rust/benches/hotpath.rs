//! `cargo bench --bench hotpath` — micro-benchmarks of the L3 hot paths
//! (hand-rolled harness; criterion is unavailable offline).
fn main() { accumkrr::bench::hotpath_main(); }
