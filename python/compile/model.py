"""L2: the sketched-KRR compute graph in JAX, calling the L1 Pallas kernels.

Three jit-able entry points, each lowered to one HLO artifact per shape
bucket by aot.py:

* fit_sketched  - the paper's eq. (3) training path for an accumulation
  sketch given as COO (idx[d, m], w[d, m]): K via the Pallas tile kernel,
  KS via the Pallas gather-accumulate kernel, the d x d system solved with
  matrix-free CG. CG (not Cholesky) is deliberate: jnp.linalg.solve /
  cholesky lower to LAPACK FFI custom-calls that the xla_extension 0.5.1
  CPU client cannot execute, while CG lowers to plain HLO (dots + while).
* predict_sketched - batched prediction from the folded (xs, w, theta).
* fit_exact - eq. (2) with the same CG trick, for the small-n buckets the
  approximation-error experiments compare against.

Scalars (lambda, bandwidth) are runtime inputs so one artifact serves every
regularisation setting of its shape bucket; the kernel family is static
(baked per artifact).
"""

import jax.numpy as jnp
from jax import lax

from .kernels import kmat, sketch_apply


def _cg_solve(a, b, iters):
    """Conjugate gradients on SPD a x = b, fixed iteration count.

    Lowers to a single HLO While of dots - compact artifact text and no
    LAPACK custom-calls. For the d <= 128 systems in our buckets, 2d
    iterations reach fp32 machine precision; cost is negligible next to
    the O(n m d) gram work.
    """

    def body(_, carry):
        x, r, p, rs = carry
        ap = a @ p
        denom = jnp.dot(p, ap)
        alpha = rs / jnp.where(denom > 0, denom, 1.0)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.where(rs > 0, rs, 1.0)
        return (x, r, r + beta * p, rs_new)

    x0 = jnp.zeros_like(b)
    x, _, _, _ = lax.fori_loop(0, iters, body, (x0, b, b, jnp.dot(b, b)))
    return x


def fit_sketched(x, y, idx, w, lam, bw, *, kind, cg_iters=None):
    """Sketched KRR fit (paper eq. 3).

    x: (n, p) f32, y: (n,) f32, idx: (d, m) i32, w: (d, m) f32,
    lam/bw: scalars. Returns (theta (d,), fitted (n,)).
    """
    n = x.shape[0]
    d = idx.shape[0]
    k = kmat.kernel_matrix(x, x, bw, kind)
    ks = sketch_apply.ks_accumulate(k, idx, w)          # (n, d)  O(n m d)
    stks = sketch_apply.st_mat(ks, idx, w)               # (d, d)  O(m d^2)
    stks = 0.5 * (stks + stks.T)
    stk2s = ks.T @ ks                                    # (d, d)
    a = stk2s + n * lam * stks
    # tiny relative jitter for collided columns (same policy as the rust path)
    a = a + (1e-7 * jnp.trace(a) / d) * jnp.eye(d, dtype=a.dtype)
    rhs = ks.T @ y
    theta = _cg_solve(a, rhs, cg_iters or 2 * d)
    fitted = ks @ theta
    return theta, fitted


def predict_sketched(xq, xs, w, theta, bw, *, kind):
    """Batched sketched-KRR prediction.

    xq: (b, p), xs: (d, m, p) sampled support points, w: (d, m),
    theta: (d,). Returns (b,).
    """
    d, m, p = xs.shape
    kq = kmat.kernel_matrix(xq, xs.reshape(d * m, p), bw, kind)
    kq = kq.reshape(xq.shape[0], d, m)
    return jnp.einsum("bdm,dm,d->b", kq, w, theta)


def fit_exact(x, y, lam, bw, *, kind, cg_iters=None):
    """Exact KRR fit (paper eq. 2) for small-n buckets.

    Returns (alpha (n,), fitted (n,)).
    """
    n = x.shape[0]
    k = kmat.kernel_matrix(x, x, bw, kind)
    a = k + n * lam * jnp.eye(n, dtype=k.dtype)
    alpha = _cg_solve(a, y, cg_iters or min(3 * n, 600))
    return alpha, k @ alpha
