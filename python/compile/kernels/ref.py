"""Pure-jnp oracles for the Pallas kernels and the L2 model.

Everything here is the straightforward O(n^2) definition; pytest checks the
Pallas kernels and the AOT'd model against these to machine tolerance.
"""

import jax.numpy as jnp

from . import kmat


def kernel_matrix_ref(x, y, bw, kind):
    """Dense cross-kernel matrix, direct definition."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = jnp.maximum(
        jnp.sum(x * x, 1)[:, None] + jnp.sum(y * y, 1)[None, :] - 2.0 * x @ y.T,
        0.0,
    )
    if kind == kmat.GAUSSIAN:
        return jnp.exp(-d2 / (2.0 * bw * bw))
    r = jnp.sqrt(d2 + 1e-30)
    if kind == kmat.MATERN12:
        return jnp.exp(-r / bw)
    if kind == kmat.MATERN32:
        a = jnp.sqrt(3.0) * r / bw
        return (1.0 + a) * jnp.exp(-a)
    if kind == kmat.MATERN52:
        a = jnp.sqrt(5.0) * r / bw
        return (1.0 + a + 5.0 * d2 / (3.0 * bw * bw)) * jnp.exp(-a)
    raise ValueError(kind)


def sketch_dense_ref(n, idx, w):
    """Materialise the sparse accumulation sketch as a dense (n, d) matrix."""
    d, m = idx.shape
    s = jnp.zeros((n, d), jnp.float32)
    for j in range(d):
        for t in range(m):
            s = s.at[idx[j, t], j].add(w[j, t])
    return s


def ks_ref(k, idx, w):
    """KS via the dense sketch."""
    s = sketch_dense_ref(k.shape[1], idx, w)
    return k.astype(jnp.float32) @ s


def fit_sketched_ref(x, y, idx, w, lam, bw, kind):
    """Direct dense implementation of the sketched KRR fit (paper eq. 3)."""
    n = x.shape[0]
    k = kernel_matrix_ref(x, x, bw, kind)
    s = sketch_dense_ref(n, idx, w)
    ks = k @ s
    stks = s.T @ ks
    stk2s = ks.T @ ks
    a = stk2s + n * lam * stks
    rhs = ks.T @ y.astype(jnp.float32)
    theta = jnp.linalg.solve(a + 1e-8 * jnp.eye(a.shape[0]), rhs)
    fitted = ks @ theta
    return theta, fitted


def predict_sketched_ref(xq, xs, w, theta, bw, kind):
    """f(x) = sum_j theta_j sum_t w[j,t] k(x, xs[j,t])."""
    d, m, p = xs.shape
    kq = kernel_matrix_ref(xq, xs.reshape(d * m, p), bw, kind).reshape(
        xq.shape[0], d, m
    )
    return jnp.einsum("bdm,dm,d->b", kq, w, theta)


def fit_exact_ref(x, y, lam, bw, kind):
    """Exact KRR (paper eq. 2)."""
    n = x.shape[0]
    k = kernel_matrix_ref(x, x, bw, kind)
    alpha = jnp.linalg.solve(k + n * lam * jnp.eye(n), y.astype(jnp.float32))
    return alpha, k @ alpha
