"""L1 Pallas kernel: accumulated-sketch application KS.

The paper's Section3.3 cost argument: for a sketch built from m accumulated
sub-sampling matrices, column j of S has entries w[j, t] at rows
idx[j, t], so

    KS[:, j] = sum_t w[j, t] * K[:, idx[j, t]]

is a gather-accumulate over at most m*d kernel columns - O(n*m*d) instead
of the dense O(n^2 d). Expressed in Pallas, a row-tile of K stays
VMEM-resident while all d output columns are accumulated from it; the
schedule over row tiles is the BlockSpec grid (on TPU this is the
HBM->VMEM pipeline the paper's "few extra matrix additions" become).

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 128


def _ks_kernel(k_ref, idx_ref, w_ref, o_ref):
    """One row-tile: o[br, d] = gather-accumulate from k[br, n].

    idx: (d, m) int32, w: (d, m) f32 - small, fully VMEM-resident.
    """
    k = k_ref[...]                      # (br, n)
    idx = idx_ref[...]                  # (d, m)
    w = w_ref[...]                      # (d, m)
    # gather columns: (br, d, m) then weighted-sum over m
    gathered = jnp.take(k, idx, axis=1)  # (br, d, m)
    o_ref[...] = jnp.einsum("rdm,dm->rd", gathered, w)


def ks_accumulate(k, idx, w, block_r=BLOCK_R):
    """Compute KS for a sparse accumulation sketch.

    k: (n, n) kernel matrix (or any (r, n) slab), idx: (d, m) int32 row
    indices, w: (d, m) weights. Returns (r, d).
    """
    r, n = k.shape
    d, m = idx.shape
    br = min(block_r, max(8, r))
    r_pad = -r % br
    kp = jnp.pad(k, ((0, r_pad), (0, 0)))
    grid = (kp.shape[0] // br,)
    out = pl.pallas_call(
        _ks_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp.shape[0], d), jnp.float32),
        interpret=True,
    )(k.astype(jnp.float32), idx.astype(jnp.int32), w.astype(jnp.float32))
    return out[:r]


def st_mat(b, idx, w):
    """S^T B for the same sparse sketch: row j = sum_t w[j,t] * B[idx[j,t], :].

    Pure-jnp gather (the d x c output is small; no tiling needed), kept next
    to the Pallas kernel because the two are always used together.
    """
    gathered = jnp.take(b, idx, axis=0)   # (d, m, c)
    return jnp.einsum("dmc,dm->dc", gathered, w)
