"""L1 Pallas kernel: tiled pairwise kernel-matrix blocks.

The paper's hot spot is forming (pieces of) the empirical kernel matrix
``K[i, j] = k(x_i, x_j)``. On TPU the natural schedule is MXU-shaped: the
squared distances over a (block_r x block_c) tile are expanded as

    d2 = |x|^2 + |y|^2 - 2 * x @ y.T

so the cross term is a (block_r, p) x (p, block_c) matmul feeding the
systolic array, and the kernel map (Gaussian / Matern) is elementwise VPU
work on the tile while it is VMEM-resident. BlockSpec expresses the
HBM->VMEM pipeline over the (rows, cols) grid.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO (see DESIGN.md
SectionHardware-Adaptation for the real-TPU cost estimate).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# kernel-family tags (static python ints baked into each artifact)
GAUSSIAN = 0
MATERN12 = 1
MATERN32 = 2
MATERN52 = 3

KIND_NAMES = {
    "gaussian": GAUSSIAN,
    "matern12": MATERN12,
    "matern32": MATERN32,
    "matern52": MATERN52,
}

# default MXU-friendly tile; shrunk automatically for small inputs
BLOCK_R = 128
BLOCK_C = 128


def _apply_kind(d2, bw, kind):
    """Elementwise kernel map on a tile of squared distances."""
    d2 = jnp.maximum(d2, 0.0)
    if kind == GAUSSIAN:
        return jnp.exp(-d2 / (2.0 * bw * bw))
    r = jnp.sqrt(d2 + 1e-30)
    if kind == MATERN12:
        return jnp.exp(-r / bw)
    if kind == MATERN32:
        a = jnp.sqrt(3.0) * r / bw
        return (1.0 + a) * jnp.exp(-a)
    if kind == MATERN52:
        a = jnp.sqrt(5.0) * r / bw
        return (1.0 + a + 5.0 * d2 / (3.0 * bw * bw)) * jnp.exp(-a)
    raise ValueError(f"unknown kernel kind {kind}")


def _kmat_kernel(x_ref, y_ref, bw_ref, o_ref, *, kind):
    """One (BLOCK_R x BLOCK_C) output tile.

    x_ref: (block_r, p) row slab, y_ref: (block_c, p) col slab. Both arrive
    in VMEM via BlockSpec; the cross term is a single MXU matmul.
    """
    x = x_ref[...]
    y = y_ref[...]
    bw = bw_ref[0]
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # (br, 1)
    yn = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, bc)
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    d2 = xn + yn - 2.0 * cross
    o_ref[...] = _apply_kind(d2, bw, kind)


def kernel_matrix(x, y, bw, kind, block_r=BLOCK_R, block_c=BLOCK_C):
    """Cross kernel matrix k(x_i, y_j) via the Pallas tile kernel.

    x: (n, p), y: (m, p), bw: scalar array. Pads n/m up to tile multiples
    and slices back (padding rows produce garbage columns that are simply
    dropped).
    """
    n, p = x.shape
    m, _ = y.shape
    br = min(block_r, max(8, n))
    bc = min(block_c, max(8, m))
    n_pad = -n % br
    m_pad = -m % bc
    xp = jnp.pad(x, ((0, n_pad), (0, 0)))
    yp = jnp.pad(y, ((0, m_pad), (0, 0)))
    grid = (xp.shape[0] // br, yp.shape[0] // bc)
    bw_arr = jnp.asarray(bw, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_kmat_kernel, kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, p), lambda i, j: (j, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32), bw_arr)
    return out[:n, :m]
