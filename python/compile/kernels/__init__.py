"""Pallas kernels (L1) + pure-jnp reference oracles."""
