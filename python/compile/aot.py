"""AOT compile path: lower the L2 model to HLO *text* artifacts + manifest.

HLO text (not ``lowered.compile().serialize()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids that xla_extension 0.5.1 (behind the published ``xla`` 0.1.6 crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Every artifact is a fixed-shape *bucket*; the rust coordinator pads
requests up to the nearest bucket (padding columns carry w = 0, padding
rows y = 0 - correctness under padding is covered by rust integration
tests). Run ``python -m compile.aot --out ../artifacts`` (the Makefile's
``make artifacts``).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import kmat

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Default shape buckets. Small enough to AOT quickly on 1 CPU core, big
# enough for the end-to-end example and serving path. Extend via --buckets.
FIT_BUCKETS = [
    # (kernel, n, p, d, m)
    ("gaussian", 512, 3, 32, 4),
    ("matern32", 512, 4, 24, 4),
]
PREDICT_BUCKETS = [
    # (kernel, batch, p, d, m)
    ("gaussian", 64, 3, 32, 4),
    ("matern32", 64, 4, 24, 4),
]
EXACT_BUCKETS = [
    # (kernel, n, p)
    ("gaussian", 256, 3),
]


def lower_fit(kind_name, n, p, d, m):
    kind = kmat.KIND_NAMES[kind_name]
    fn = functools.partial(model.fit_sketched, kind=kind)
    return jax.jit(fn).lower(
        spec((n, p)), spec((n,)), spec((d, m), I32), spec((d, m)),
        spec(()), spec(()),
    )


def lower_predict(kind_name, b, p, d, m):
    kind = kmat.KIND_NAMES[kind_name]
    fn = functools.partial(model.predict_sketched, kind=kind)
    return jax.jit(fn).lower(
        spec((b, p)), spec((d, m, p)), spec((d, m)), spec((d,)), spec(()),
    )


def lower_exact(kind_name, n, p):
    kind = kmat.KIND_NAMES[kind_name]
    fn = functools.partial(model.fit_exact, kind=kind)
    return jax.jit(fn).lower(spec((n, p)), spec((n,)), spec(()), spec(()))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}

    def emit(name, lowered, meta):
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "file": f"{name}.hlo.txt", **meta})
        print(f"  {name}: {len(text)} chars")

    for kind, n, p, d, m in FIT_BUCKETS:
        name = f"fit_{kind}_n{n}_p{p}_d{d}_m{m}"
        emit(name, lower_fit(kind, n, p, d, m),
             {"entry": "fit_sketched", "kernel": kind, "n": n, "p": p, "d": d, "m": m})

    for kind, b, p, d, m in PREDICT_BUCKETS:
        name = f"predict_{kind}_b{b}_p{p}_d{d}_m{m}"
        emit(name, lower_predict(kind, b, p, d, m),
             {"entry": "predict_sketched", "kernel": kind, "b": b, "p": p, "d": d, "m": m})

    for kind, n, p in EXACT_BUCKETS:
        name = f"exact_{kind}_n{n}_p{p}"
        emit(name, lower_exact(kind, n, p),
             {"entry": "fit_exact", "kernel": kind, "n": n, "p": p})

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
