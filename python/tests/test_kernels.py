"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and kernel families; every case asserts allclose
against ref.py. This is the core build-time correctness signal for the
artifacts the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kmat, ref, sketch_apply

KINDS = [kmat.GAUSSIAN, kmat.MATERN12, kmat.MATERN32, kmat.MATERN52]


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("kind", KINDS)
def test_kmat_matches_ref_basic(kind):
    k1, k2 = jax.random.split(jax.random.PRNGKey(kind))
    x = rand(k1, 50, 3)
    y = rand(k2, 37, 3)
    got = kmat.kernel_matrix(x, y, 1.3, kind)
    want = ref.kernel_matrix_ref(x, y, 1.3, kind)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 70),
    m=st.integers(1, 70),
    p=st.integers(1, 6),
    kind=st.sampled_from(KINDS),
    bw=st.floats(0.2, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmat_matches_ref_hypothesis(n, m, p, kind, bw, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(k1, n, p)
    y = rand(k2, m, p)
    got = kmat.kernel_matrix(x, y, bw, kind)
    want = ref.kernel_matrix_ref(x, y, bw, kind)
    assert got.shape == (n, m)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_kmat_symmetric_unit_diag():
    x = rand(jax.random.PRNGKey(3), 40, 4)
    k = kmat.kernel_matrix(x, x, 0.9, kmat.GAUSSIAN)
    np.testing.assert_allclose(k, k.T, rtol=0, atol=1e-6)
    np.testing.assert_allclose(jnp.diag(k), jnp.ones(40), rtol=0, atol=1e-5)


def test_kmat_nonsquare_tiles():
    # force the padding path: sizes not multiples of the block
    x = rand(jax.random.PRNGKey(4), 130, 2)
    y = rand(jax.random.PRNGKey(5), 129, 2)
    got = kmat.kernel_matrix(x, y, 1.0, kmat.MATERN32, block_r=64, block_c=64)
    want = ref.kernel_matrix_ref(x, y, 1.0, kmat.MATERN32)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 80),
    d=st.integers(1, 12),
    m=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_ks_accumulate_matches_ref(n, d, m, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    k = rand(k1, n, n)
    idx = jax.random.randint(k2, (d, m), 0, n, jnp.int32)
    w = rand(k3, d, m)
    got = sketch_apply.ks_accumulate(k, idx, w)
    want = ref.ks_ref(k, idx, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ks_accumulate_rectangular_slab():
    key = jax.random.PRNGKey(11)
    k1, k2, k3 = jax.random.split(key, 3)
    k = rand(k1, 37, 90)  # row slab of a bigger K
    idx = jax.random.randint(k2, (5, 3), 0, 90, jnp.int32)
    w = rand(k3, 5, 3)
    got = sketch_apply.ks_accumulate(k, idx, w)
    want = ref.ks_ref(k, idx, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_st_mat_matches_dense():
    key = jax.random.PRNGKey(12)
    k1, k2, k3 = jax.random.split(key, 3)
    b = rand(k1, 30, 7)
    idx = jax.random.randint(k2, (6, 4), 0, 30, jnp.int32)
    w = rand(k3, 6, 4)
    s = ref.sketch_dense_ref(30, idx, w)
    got = sketch_apply.st_mat(b, idx, w)
    np.testing.assert_allclose(got, s.T @ b, rtol=1e-4, atol=1e-5)


def test_duplicate_indices_accumulate():
    # the same row sampled twice in one column must add its weights
    k = jnp.eye(4, dtype=jnp.float32)
    idx = jnp.array([[2, 2]], jnp.int32)
    w = jnp.array([[0.5, 0.25]], jnp.float32)
    got = sketch_apply.ks_accumulate(k, idx, w)
    want = jnp.zeros((4, 1)).at[2, 0].set(0.75)
    np.testing.assert_allclose(got, want, atol=1e-7)
