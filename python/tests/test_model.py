"""L2 correctness: the jit-able model vs dense reference implementations."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import kmat, ref


def problem(n=60, p=3, d=8, m=4, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.uniform(k1, (n, p), jnp.float32)
    y = jnp.sin(3.0 * x[:, 0]) + 0.1 * jax.random.normal(k2, (n,), jnp.float32)
    idx = jax.random.randint(k3, (d, m), 0, n, jnp.int32)
    # algorithm-1 weights: r / sqrt(d m p_i) with uniform p = 1/n
    sign = jnp.where(jax.random.bernoulli(k4, 0.5, (d, m)), 1.0, -1.0)
    w = sign * np.sqrt(n / (d * m))
    return x, y, idx, w.astype(jnp.float32)


@pytest.mark.parametrize("kind", [kmat.GAUSSIAN, kmat.MATERN32])
def test_fit_sketched_matches_dense_reference(kind):
    x, y, idx, w = problem(seed=kind)
    lam, bw = 1e-3, 0.7
    theta, fitted = model.fit_sketched(x, y, idx, w, lam, bw, kind=kind)
    theta_ref, fitted_ref = ref.fit_sketched_ref(x, y, idx, w, lam, bw, kind)
    np.testing.assert_allclose(fitted, fitted_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(theta, theta_ref, rtol=2e-2, atol=2e-2)


def test_fit_sketched_jits_and_is_deterministic():
    x, y, idx, w = problem(seed=7)
    fn = jax.jit(functools.partial(model.fit_sketched, kind=kmat.GAUSSIAN))
    t1, f1 = fn(x, y, idx, w, 1e-3, 0.5)
    t2, f2 = fn(x, y, idx, w, 1e-3, 0.5)
    np.testing.assert_array_equal(t1, t2)
    assert f1.shape == (60,)


def test_predict_sketched_matches_ref():
    x, y, idx, w = problem(seed=3)
    lam, bw = 1e-3, 0.7
    theta, _ = model.fit_sketched(x, y, idx, w, lam, bw, kind=kmat.GAUSSIAN)
    d, m = idx.shape
    xs = x[idx.reshape(-1)].reshape(d, m, x.shape[1])
    xq = jax.random.uniform(jax.random.PRNGKey(9), (17, x.shape[1]), jnp.float32)
    got = model.predict_sketched(xq, xs, w, theta, bw, kind=kmat.GAUSSIAN)
    want = ref.predict_sketched_ref(xq, xs, w, theta, bw, kmat.GAUSSIAN)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_predict_consistent_with_fit_on_train_points():
    # predicting at the training points must reproduce the fitted values
    x, y, idx, w = problem(n=50, seed=5)
    lam, bw = 1e-3, 0.6
    theta, fitted = model.fit_sketched(x, y, idx, w, lam, bw, kind=kmat.GAUSSIAN)
    d, m = idx.shape
    xs = x[idx.reshape(-1)].reshape(d, m, x.shape[1])
    pred = model.predict_sketched(x, xs, w, theta, bw, kind=kmat.GAUSSIAN)
    np.testing.assert_allclose(pred, fitted, rtol=1e-3, atol=1e-3)


def test_fit_exact_matches_ref():
    x, y, _, _ = problem(n=40, seed=11)
    lam, bw = 1e-2, 0.8
    alpha, fitted = model.fit_exact(x, y, lam, bw, kind=kmat.GAUSSIAN)
    alpha_ref, fitted_ref = ref.fit_exact_ref(x, y, lam, bw, kmat.GAUSSIAN)
    np.testing.assert_allclose(fitted, fitted_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(alpha, alpha_ref, rtol=1e-2, atol=1e-2)


def test_full_sketch_recovers_exact():
    # d = n, m = 1, identity-like sketch: sketched fit == exact fit
    n = 30
    x, y, _, _ = problem(n=n, seed=13)
    idx = jnp.arange(n, dtype=jnp.int32)[:, None]
    w = jnp.ones((n, 1), jnp.float32)
    lam, bw = 1e-3, 0.6
    _, fitted_s = model.fit_sketched(x, y, idx, w, lam, bw, kind=kmat.GAUSSIAN)
    _, fitted_e = model.fit_exact(x, y, lam, bw, kind=kmat.GAUSSIAN)
    # the sketched path solves the squared system (condition number k(K)^2),
    # so fp32 CG leaves a few 1e-2 of slack on ill-conditioned RBF grams
    np.testing.assert_allclose(fitted_s, fitted_e, rtol=3e-2, atol=3e-2)
