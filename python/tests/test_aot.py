"""AOT path: lowering produces parseable HLO text with the expected
parameter signature, and contains no LAPACK/FFI custom-calls (which the
xla_extension 0.5.1 CPU client behind the rust runtime cannot execute).
"""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


def test_fit_lowering_emits_custom_call_free_hlo():
    text = aot.to_hlo_text(aot.lower_fit("gaussian", 64, 3, 8, 2))
    assert "ENTRY" in text
    assert "custom-call" not in text, "artifact would not run on the rust CPU client"


def test_predict_lowering_emits_custom_call_free_hlo():
    text = aot.to_hlo_text(aot.lower_predict("matern32", 16, 4, 8, 2))
    assert "ENTRY" in text
    assert "custom-call" not in text


def test_exact_lowering_emits_custom_call_free_hlo():
    text = aot.to_hlo_text(aot.lower_exact("gaussian", 32, 3))
    assert "ENTRY" in text
    assert "custom-call" not in text


def test_fit_hlo_has_expected_parameters():
    text = aot.to_hlo_text(aot.lower_fit("gaussian", 64, 3, 8, 2))
    # x, y, idx, w, lam, bw = 6 parameters
    assert "f32[64,3]" in text
    assert "s32[8,2]" in text


@pytest.mark.slow
def test_full_aot_run_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) >= 5
    for a in manifest["artifacts"]:
        assert (out / a["file"]).exists()
