//! Quickstart: fit a sketched KRR model with the paper's accumulation
//! sketch and compare it against exact KRR and the two extremes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use accumkrr::data::{bimodal, BimodalConfig};
use accumkrr::kernels::{kernel_matrix, Kernel};
use accumkrr::krr::{KrrModel, SketchedKrr};
use accumkrr::rng::Pcg64;
use accumkrr::sketch::{SketchBuilder, SketchKind};
use accumkrr::stats::in_sample_sq_error;
use accumkrr::util::timer::timed;

fn main() {
    let n = 1000;
    let mut rng = Pcg64::seed(1);

    // 1. data: the paper's bimodal distribution (high incoherence)
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _truth) = bimodal(&cfg, &mut rng);

    // 2. paper schedules: λ = 0.5·n^{−4/7}, d = ⌊1.3·n^{3/7}⌋, Gaussian
    //    kernel with bw = 1.5·n^{−1/7}
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let d = (1.3 * (n as f64).powf(3.0 / 7.0)) as usize;
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    println!("n={n}  d={d}  lambda={lambda:.5}  kernel={} bw={:.3}", kern.name(), kern.bandwidth);

    // 3. exact KRR reference (O(n³) — this is what sketching avoids)
    let k = kernel_matrix(&kern, &x);
    let (exact, exact_secs) = timed(|| KrrModel::fit_with_k(kern, &x, &k, &y, lambda).unwrap());
    println!("exact KRR:               {exact_secs:>8.3}s");

    // 4. three sketches at the same d
    for (name, kind) in [
        ("nystrom (m=1)", SketchKind::Nystrom),
        ("accumulation (m=4)", SketchKind::Accumulation { m: 4 }),
        ("gaussian (m=inf)", SketchKind::Gaussian),
    ] {
        let (model, secs) = timed(|| {
            let s = SketchBuilder::new(kind.clone()).build(n, d, &mut rng);
            SketchedKrr::fit(kern, &x, &y, &s, lambda, None).unwrap()
        });
        let err = in_sample_sq_error(model.fitted(), exact.fitted());
        println!(
            "{name:<24} {secs:>8.3}s  approx_err={err:.3e}  landmarks={}",
            model.num_landmarks()
        );
    }
    println!("\nexpected shape: accumulation error ~ gaussian error, runtime ~ nystrom.");
}
