//! Adaptive-m accumulation: let the runtime discover how many
//! sub-sampling terms the data needs instead of fixing `m` up front.
//!
//! ```bash
//! cargo run --release --example adaptive_m
//! ```

use accumkrr::data::{bimodal, BimodalConfig};
use accumkrr::kernels::Kernel;
use accumkrr::krr::{AdaptiveOptions, KrrModel, SketchedKrr};
use accumkrr::rng::Pcg64;
use accumkrr::sketch::{SketchBuilder, SketchKind};
use accumkrr::stats::in_sample_sq_error;
use accumkrr::util::timer::timed;

fn main() {
    let n = 1500;
    let mut rng = Pcg64::seed(17);

    // high-incoherence data: the regime where the right m is largest
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let d = (1.5 * (n as f64).powf(3.0 / 7.0)) as usize;
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    println!("n={n}  d={d}  lambda={lambda:.5}");

    let (exact, exact_secs) = timed(|| KrrModel::fit(kern, &x, &y, lambda).unwrap());
    println!("exact KRR reference:      {exact_secs:>7.3}s");

    // adaptive fit: grows m until θ stabilises, re-using every kernel
    // evaluation and Gram entry along the way
    let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
    let opts = AdaptiveOptions {
        m_max: 64,
        rel_tol: 1e-2,
        ..Default::default()
    };
    let mut fit_rng = Pcg64::seed(18);
    let ((model, trace), ada_secs) = timed(|| {
        SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lambda, &opts, &mut fit_rng)
            .expect("adaptive fit")
    });
    let rep = *model.report();
    println!(
        "adaptive fit:             {ada_secs:>7.3}s  → chose m={} in {} rounds \
         ({} rank updates, {} refactors, {} kernel evals)",
        rep.m, rep.rounds, rep.rank_updates, rep.refactors, rep.kernel_evals
    );
    for r in &trace {
        println!(
            "   round m={:<3} Δθ/θ={:<10.3e} {}  {:.4}s",
            r.m,
            if r.rel_change.is_finite() { r.rel_change } else { f64::NAN },
            if r.refactored { "refactor" } else { "rank-upd" },
            r.secs
        );
    }
    let ada_err = in_sample_sq_error(model.fitted(), exact.fitted());
    println!("adaptive approx error:    {ada_err:.3e}");

    // the fixed-m alternatives the adaptive loop replaces
    for m in [1usize, rep.m, 64] {
        let mut rng = Pcg64::seed(18);
        let (skrr, secs) = timed(|| {
            let s = SketchBuilder::new(SketchKind::Accumulation { m }).build(n, d, &mut rng);
            SketchedKrr::fit(kern, &x, &y, &s, lambda, None).unwrap()
        });
        let err = in_sample_sq_error(skrr.fitted(), exact.fitted());
        println!("fixed m={m:<3}               {secs:>7.3}s  approx error {err:.3e}");
    }
    println!(
        "\nthe adaptive fit lands at fixed-m={} accuracy while paying for the\n\
         m-sweep only once (incremental Grams + rank-updated solves).",
        rep.m
    );
}
