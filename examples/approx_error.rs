//! Figure-2 scenario as a standalone example: approximation error vs the
//! accumulation level m at fixed (n, d) — the paper's core empirical claim
//! that a medium m reaches Gaussian-sketch accuracy.
//!
//! ```bash
//! cargo run --release --example approx_error -- [n] [replicates]
//! ```

use accumkrr::bench::{print_table, run_fig2, BenchOpts};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);
    let replicates = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(5);
    let opts = BenchOpts {
        replicates,
        n_max: n,
        ..Default::default()
    };
    let rows = run_fig2(&opts);
    print_table(
        &format!("figure 2: approximation error vs (d, m) at n={n}"),
        &rows,
        &None,
    );
    println!("\nread: each m-curve decays with d; m=16/32 hug the m=inf (gaussian) curve,");
    println!("m=1 (nystrom) needs a much larger d for the same error.");
}
