//! Figure-3/4 scenario as a standalone example: accuracy-vs-efficiency
//! trade-off of the four candidate methods on the (simulated) UCI
//! datasets.
//!
//! ```bash
//! cargo run --release --example tradeoff -- [dataset] [n_max] [replicates]
//! # dataset ∈ {rqa, casp, gas}
//! ```

use accumkrr::bench::{print_table, run_fig3, BenchOpts};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let dataset = args.get(1).cloned().unwrap_or_else(|| "rqa".into());
    let n_max = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2000);
    let replicates = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(5);
    let opts = BenchOpts {
        replicates,
        n_max,
        ..Default::default()
    };
    let rows = run_fig3(&opts, &[dataset.as_str()]);
    print_table(
        &format!("figure 3: accuracy vs efficiency on {dataset}"),
        &rows,
        &None,
    );
    println!("\nread: accum_m4 reaches gaussian-level test error at nystrom-level runtime;");
    println!("verysparse lands in between; bless pays the leverage-score estimation.");
}
