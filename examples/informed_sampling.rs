//! Informed sampling end to end: feed a ridge-leverage profile into the
//! accumulation sketch, compare against uniform draws and Poisson
//! inclusion, and let an adaptive fit refine its own probabilities
//! between terms.
//!
//! ```bash
//! cargo run --release --example informed_sampling
//! ```

use accumkrr::data::{bimodal, BimodalConfig};
use accumkrr::kernels::{kernel_matrix, Kernel};
use accumkrr::krr::{AdaptiveOptions, KrrModel, SketchedKrr};
use accumkrr::leverage::{exact_scores, stat_dim_from_scores};
use accumkrr::rng::{AliasTable, Pcg64};
use accumkrr::sketch::{Sampling, SketchBuilder, SketchKind, SketchOps};

fn rel_err(got: &[f64], want: &[f64]) -> f64 {
    let num: f64 = got
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = want.iter().map(|b| b * b).sum::<f64>().sqrt();
    num / den.max(1e-300)
}

fn main() {
    let n = 400;
    let mut rng = Pcg64::seed(29);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let d = (1.5 * (n as f64).powf(3.0 / 7.0)) as usize;
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));

    // the reference everything is measured against
    let exact = KrrModel::fit(kern, &x, &y, lambda).expect("exact fit");

    // the informed profile: exact ridge-leverage scores at the training λ
    // (past n ≈ 512 you would switch to accumkrr::leverage::bless — same
    // profile, streamed, never n×n)
    let scores = exact_scores(&kernel_matrix(&kern, &x), lambda);
    println!(
        "n={n}  d={d}  d_stat={:.1} (effective dimension of the profile)",
        stat_dim_from_scores(&scores)
    );

    // error-vs-m: uniform vs leverage-weighted accumulation, same seeds
    for m in [1usize, 2, 4, 8] {
        let mut uni_rng = Pcg64::seed(101);
        let uni = SketchBuilder::new(SketchKind::Accumulation { m }).build(n, d, &mut uni_rng);
        let uni_fit = SketchedKrr::fit(kern, &x, &y, &uni, lambda, None).expect("uniform fit");

        let mut lev_rng = Pcg64::seed(101);
        let lev = SketchBuilder::new(SketchKind::Accumulation { m })
            .with_sampling(Sampling::Weighted(AliasTable::new(&scores)))
            .build(n, d, &mut lev_rng);
        let lev_fit = SketchedKrr::fit(kern, &x, &y, &lev, lambda, None).expect("leverage fit");

        println!(
            "m={m:>2}  uniform rel_err={:.4}  leverage rel_err={:.4}",
            rel_err(uni_fit.fitted(), exact.fitted()),
            rel_err(lev_fit.fitted(), exact.fitted()),
        );
    }

    // Poisson inclusion: every row enters independently with probability
    // min(1, d·pᵢ), reweighted so E[SᵀS] = I — one draw, no terms
    let mut poi_rng = Pcg64::seed(101);
    let poi = SketchBuilder::new(SketchKind::Nystrom)
        .with_sampling(Sampling::Poisson(AliasTable::new(&scores)))
        .build(n, 4 * d, &mut poi_rng);
    let poi_fit = SketchedKrr::fit(kern, &x, &y, &poi, lambda, None).expect("poisson fit");
    println!(
        "poisson (d_target={})  realised_d={}  rel_err={:.4}",
        4 * d,
        poi.d(),
        rel_err(poi_fit.fitted(), exact.fitted()),
    );

    // between-term refinement: start uniform, estimate leverage from the
    // support columns the fit has already paid for, finish informed
    let opts = AdaptiveOptions {
        m_max: 16,
        rel_tol: 0.05,
        refine_after_m: 1,
        ..Default::default()
    };
    let builder = SketchBuilder::new(SketchKind::Accumulation { m: 1 });
    let mut ada_rng = Pcg64::seed(101);
    let (model, trace) =
        SketchedKrr::fit_adaptive(kern, &x, &y, &builder, d, lambda, &opts, &mut ada_rng)
            .expect("adaptive fit");
    let rep = *model.report();
    println!(
        "adaptive+refine: chose m={} in {} rounds, refined at round {} (d_stat={:.1})",
        rep.m,
        rep.rounds,
        rep.refine_round,
        rep.d_stat,
    );
    for r in &trace {
        println!(
            "  round m={:>2}  rel_change={:>9.2e}  drawn_from={}",
            r.m,
            if r.rel_change.is_finite() { r.rel_change } else { -1.0 },
            if r.refined { "estimated leverage" } else { "uniform" },
        );
    }
}
