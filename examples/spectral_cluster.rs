//! Spectral clustering on synthetic two-moons: sketched vs exact
//! embedding, ARI against the ground truth.
//!
//! ```bash
//! cargo run --release --example spectral_cluster
//! ```
//!
//! Two stories in one run:
//!
//! 1. **Two moons** — linearly inseparable, a thin spectral gap
//!    (λ₂ − λ₃ of the normalized affinity ≈ 5e-3 at this bandwidth).
//!    The streamed operator route nails it; the sketched pencil shows
//!    its accuracy improving with the number of accumulated terms `m` —
//!    exactly the paper's Nyström → Gaussian interpolation, now on an
//!    eigenvector problem. On thin-gap graphs the pencil needs the
//!    sketch error *below the gap*, so watch the ARI climb with `m`.
//! 2. **Blobs** — a wide gap: even `m = 1` (pure Nyström landmarks)
//!    recovers the exact embedding, and the adaptive rule stops almost
//!    immediately.

use accumkrr::cluster::{
    adjusted_rand_index, max_principal_sine, EmbedMethod, SpectralClustering, SpectralOptions,
};
use accumkrr::data::{blobs, two_moons};
use accumkrr::kernels::Kernel;
use accumkrr::rng::Pcg64;
use accumkrr::util::timer::timed;

fn main() {
    let mut rng = Pcg64::seed(7);

    // ---- two moons: exact (operator) embedding vs sketched pencil ----
    let n = 600;
    let (x, truth) = two_moons(n, 0.06, &mut rng);
    let kern = Kernel::gaussian(0.15); // below the ≈0.3 inter-moon gap
    println!("two moons: n={n}, gaussian bw=0.15");

    let exact_opts = SpectralOptions {
        k: 2,
        ..Default::default()
    };
    let (exact, secs) =
        timed(|| SpectralClustering::fit(kern, &x, &exact_opts, &mut rng).unwrap());
    println!(
        "  operator (exact embedding): {secs:>6.3}s  ARI {:.4}  bottom eigenvalues {:?}",
        adjusted_rand_index(&exact.labels, &truth),
        exact
            .eigenvalues
            .iter()
            .map(|v| (v * 1e4).round() / 1e4)
            .collect::<Vec<_>>()
    );

    for m in [1usize, 4, 8, 16] {
        let opts = SpectralOptions {
            k: 2,
            method: EmbedMethod::Sketched { d: 48, m },
            ..Default::default()
        };
        let (fit, secs) = timed(|| SpectralClustering::fit(kern, &x, &opts, &mut rng).unwrap());
        println!(
            "  sketched pencil d=48 m={m:<2}: {secs:>6.3}s  ARI {:.4}  subspace sin vs exact {:.3}",
            adjusted_rand_index(&fit.labels, &truth),
            max_principal_sine(&fit.embedding, &exact.embedding),
        );
    }

    // ---- blobs: wide gap, adaptive m stops early ----
    let (bx, btruth) = blobs(600, 3, 6.0, 0.3, &mut rng);
    let bkern = Kernel::gaussian(1.5);
    println!("\nthree blobs: n=600, gaussian bw=1.5");
    let (bexact, secs) = timed(|| {
        SpectralClustering::fit(
            bkern,
            &bx,
            &SpectralOptions {
                k: 3,
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap()
    });
    println!(
        "  operator (exact embedding): {secs:>6.3}s  ARI {:.4}",
        adjusted_rand_index(&bexact.labels, &btruth)
    );
    let (bfit, secs) = timed(|| {
        SpectralClustering::fit(
            bkern,
            &bx,
            &SpectralOptions {
                k: 3,
                method: EmbedMethod::Adaptive {
                    d: 32,
                    m_max: 16,
                    rel_tol: 5e-2,
                },
                ..Default::default()
            },
            &mut rng,
        )
        .unwrap()
    });
    println!(
        "  adaptive pencil (d=32):     {secs:>6.3}s  ARI {:.4}  chose m={}  subspace sin vs exact {:.2e}",
        adjusted_rand_index(&bfit.labels, &btruth),
        bfit.chosen_m.unwrap(),
        max_principal_sine(&bfit.embedding, &bexact.embedding),
    );
    println!("\nexpected shape: moons ARI climbs with m (thin gap needs sketch error");
    println!("below it); blobs are exact from m=1 and the adaptive rule stops early.");
}
