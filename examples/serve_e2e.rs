//! End-to-end driver (the DESIGN.md E2E experiment): proves all three
//! layers compose on a real small workload.
//!
//! 1. **L1/L2 via PJRT**: fit sketched KRR through the AOT-compiled
//!    JAX/Pallas artifact and cross-check against the native Rust path.
//! 2. **L3 serving**: train a model in the coordinator, start the TCP
//!    server, fire concurrent batched prediction requests, and report
//!    latency/throughput plus batching effectiveness.
//! 3. Report the paper's headline metric: approximation error of the
//!    accumulation sketch vs Nyström/Gaussian at equal d.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use accumkrr::coordinator::{serve, ModelStore, ServerConfig, TrainRequest};
use accumkrr::data::{bimodal, BimodalConfig};
use accumkrr::kernels::{kernel_matrix, Kernel};
use accumkrr::krr::{KrrModel, SketchedKrr};
use accumkrr::rng::Pcg64;
use accumkrr::runtime::ModelRuntime;
use accumkrr::sketch::{Sketch, SketchBuilder, SketchKind};
use accumkrr::stats::in_sample_sq_error;
use accumkrr::util::json::Json;
use accumkrr::util::timer::{timing_stats, Timer};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn main() {
    println!("=== accumkrr end-to-end driver ===\n");
    part1_pjrt();
    part2_serving();
    part3_headline();
    println!("\nE2E complete.");
}

/// L1/L2 through PJRT, cross-checked against native Rust.
fn part1_pjrt() {
    println!("--- part 1: AOT artifact execution (python never on this path) ---");
    let rt = match ModelRuntime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: {e}\n(run `make artifacts` first)\n");
            return;
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let n = 512;
    let d = 32;
    let mut rng = Pcg64::seed(2024);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(0.6);
    let lam = 1e-3;
    let sketch = SketchBuilder::new(SketchKind::Accumulation { m: 4 }).build(n, d, &mut rng);
    let Sketch::Sparse(sp) = &sketch else { unreachable!() };

    let t = Timer::start();
    let pjrt_fit = rt
        .fit_sketched("gaussian", &x, &y, sp, lam, kern.bandwidth)
        .expect("pjrt fit");
    let cold_secs = t.secs(); // includes one-time artifact compilation
    let t = Timer::start();
    let _ = rt
        .fit_sketched("gaussian", &x, &y, sp, lam, kern.bandwidth)
        .expect("pjrt fit (warm)");
    let warm_secs = t.secs(); // steady-state execute
    let t = Timer::start();
    let native = SketchedKrr::fit(kern, &x, &y, &sketch, lam, None).expect("native fit");
    let native_secs = t.secs();
    let agreement = in_sample_sq_error(&pjrt_fit.fitted, native.fitted());
    println!(
        "fit n={n} d={d} m=4: pjrt({}) cold {:.3}s / warm {:.4}s vs native {:.4}s; fitted-value MSE between paths = {:.3e}",
        pjrt_fit.artifact, cold_secs, warm_secs, native_secs, agreement
    );
    assert!(agreement < 1e-3, "pjrt and native paths must agree");
    println!("agreement OK (f32 artifact vs f64 native)\n");
}

/// Serving: train via TCP, concurrent clients, batched predictions.
fn part2_serving() {
    println!("--- part 2: coordinator serving (TCP, dynamic batching) ---");
    let store = Arc::new(ModelStore::new());
    store
        .train(&TrainRequest {
            name: "rqa-accum".into(),
            dataset: "rqa".into(),
            n: 2000,
            kind: SketchKind::Accumulation { m: 4 },
            d: 0,      // paper schedule
            lambda: 0.0, // paper schedule
            bandwidth: 0.0,
            seed: 7,
            adaptive: None,
        })
        .expect("train");
    let meta = store.get("rqa-accum").unwrap();
    println!(
        "trained rqa-accum: n={} landmarks={} train_mse={:.4} train_secs={:.3}",
        meta.n_train,
        meta.model.num_landmarks(),
        meta.train_mse,
        meta.train_secs
    );

    let addr = serve(
        store,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        },
        false,
    )
    .expect("serve");

    // concurrent clients
    let clients = 8;
    let requests_per_client = 25;
    let t = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        handles.push(std::thread::spawn(move || {
            let conn = TcpStream::connect(addr).unwrap();
            conn.set_nodelay(true).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = BufReader::new(conn);
            let mut latencies = Vec::new();
            let mut rng = Pcg64::seed(100 + c as u64);
            for _ in 0..requests_per_client {
                let x: Vec<String> = (0..3)
                    .map(|_| {
                        format!(
                            "[{:.4},{:.4},{:.4},{:.4}]",
                            rng.uniform(),
                            rng.uniform(),
                            rng.uniform(),
                            rng.uniform()
                        )
                    })
                    .collect();
                let req = format!(r#"{{"op":"predict","model":"rqa-accum","x":[{}]}}"#, x.join(","));
                let t = Timer::start();
                writeln!(writer, "{req}").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                latencies.push(t.secs());
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
            }
            latencies
        }));
    }
    let mut all: Vec<f64> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let wall = t.secs();
    let st = timing_stats(&all);
    let total_queries = clients * requests_per_client * 3;
    println!(
        "served {} requests ({} rows) from {clients} concurrent clients in {wall:.3}s",
        clients * requests_per_client,
        total_queries
    );
    println!(
        "latency per request: median {:.2}ms  p25 {:.2}ms  p75 {:.2}ms  max {:.2}ms",
        st.median * 1e3,
        st.p25 * 1e3,
        st.p75 * 1e3,
        st.max * 1e3
    );
    println!("throughput: {:.0} rows/s", total_queries as f64 / wall);

    // read batching metrics
    let conn = TcpStream::connect(addr).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    writeln!(writer, r#"{{"op":"metrics"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let q = j.get("queries").and_then(|v| v.as_usize()).unwrap_or(0);
    let b = j.get("batches").and_then(|v| v.as_usize()).unwrap_or(1);
    println!(
        "dynamic batching: {q} rows in {b} batches ({:.2} rows/batch)\n",
        q as f64 / b as f64
    );
    writeln!(writer, r#"{{"op":"shutdown"}}"#).unwrap();
}

/// The paper's headline: accumulation ≈ Gaussian accuracy at ≈ Nyström cost.
fn part3_headline() {
    println!("--- part 3: headline metric (paper Fig. 1 shape) ---");
    let n = 1500;
    let mut rng = Pcg64::seed(31);
    let cfg = BimodalConfig {
        n,
        gamma: 0.5,
        ..Default::default()
    };
    let (x, y, _) = bimodal(&cfg, &mut rng);
    let kern = Kernel::gaussian(1.5 * (n as f64).powf(-1.0 / 7.0));
    let lambda = 0.5 * (n as f64).powf(-4.0 / 7.0);
    let d = (1.3 * (n as f64).powf(3.0 / 7.0)) as usize;
    let k = kernel_matrix(&kern, &x);
    let exact = KrrModel::fit_with_k(kern, &x, &k, &y, lambda).unwrap();
    let reps = 5;
    println!("n={n} d={d} ({reps} replicates)");
    let mut summary: Vec<(String, f64, f64)> = Vec::new();
    for (name, kind) in [
        ("nystrom", SketchKind::Nystrom),
        ("accum_m4", SketchKind::Accumulation { m: 4 }),
        ("gaussian", SketchKind::Gaussian),
    ] {
        let mut errs = Vec::new();
        let mut secs = Vec::new();
        for _ in 0..reps {
            let t = Timer::start();
            let s = SketchBuilder::new(kind.clone()).build(n, d, &mut rng);
            let m = SketchedKrr::fit(kern, &x, &y, &s, lambda, None).unwrap();
            secs.push(t.secs());
            errs.push(in_sample_sq_error(m.fitted(), exact.fitted()));
        }
        let err = errs.iter().sum::<f64>() / reps as f64;
        let sec = secs.iter().sum::<f64>() / reps as f64;
        println!("  {name:<10} approx_err={err:.3e}  fit_secs={sec:.3}");
        summary.push((name.into(), err, sec));
    }
    let nys = &summary[0];
    let acc = &summary[1];
    let gau = &summary[2];
    println!(
        "\nheadline: accum err is {:.1}x better than nystrom; {:.1}x of gaussian err; {:.1}x faster than gaussian",
        nys.1 / acc.1,
        acc.1 / gau.1,
        gau.2 / acc.2
    );
}
